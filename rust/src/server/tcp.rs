//! TCP listener: one line-JSON session per connection, handled on a
//! fixed thread pool, requests resolved through the model registry and
//! routed through the coordinator.

use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use super::protocol::{Request, Response, StreamStatus};
use crate::coordinator::InferBackend;
use crate::dataset::synth;
use crate::platform::dispatch;
use crate::registry::ModelRegistry;
use crate::util::json::{Json, JsonObj};
use crate::util::threadpool::ThreadPool;
use crate::util::trace::{event, Trace, TraceSampler, TraceStore};

/// Hard cap on one protocol line.  The largest legitimate request is a
/// `classify_batch` of `protocol::MAX_BATCH_IMAGES` (= 64) images; at a
/// worst-case ~20 text bytes per float (full f64 precision plus comma),
/// 64 × 27648 floats ≈ 36 MB of JSON, so 64 MiB leaves real headroom.
/// Anything beyond this is a hostile or broken client and gets a
/// structured error instead of an unbounded allocation.
pub const MAX_LINE_BYTES: usize = 64 * 1024 * 1024;

/// Read one `\n`-terminated line with a byte budget.
///
/// Returns `Ok(None)` at clean EOF, `Ok(Some(Err(())))` when the line
/// exceeded `MAX_LINE_BYTES` (the oversized tail is drained so the
/// session can continue), and IO errors otherwise.
fn read_line_bounded(
    reader: &mut impl BufRead,
    buf: &mut Vec<u8>,
) -> std::io::Result<Option<Result<(), ()>>> {
    buf.clear();
    let mut oversized = false;
    loop {
        let chunk = reader.fill_buf()?;
        if chunk.is_empty() {
            // EOF: a final unterminated line still counts as a line
            return Ok(match (oversized, buf.is_empty()) {
                (true, _) => Some(Err(())),
                (false, true) => None,
                (false, false) => Some(Ok(())),
            });
        }
        if let Some(nl) = chunk.iter().position(|&b| b == b'\n') {
            if !oversized && buf.len() + nl <= MAX_LINE_BYTES {
                buf.extend_from_slice(&chunk[..nl]);
            } else {
                oversized = true;
            }
            reader.consume(nl + 1);
            return Ok(Some(if oversized { Err(()) } else { Ok(()) }));
        }
        let take = chunk.len();
        if !oversized && buf.len() + take <= MAX_LINE_BYTES {
            buf.extend_from_slice(chunk);
        } else {
            oversized = true;
            buf.clear(); // stop buffering a hostile line
        }
        reader.consume(take);
    }
}

/// Default per-session deadline on blocking response writes: a client
/// that stops reading for this long is disconnected (and counted in
/// the `stats` op) instead of pinning a session-pool thread forever.
pub const DEFAULT_WRITE_TIMEOUT: Duration = Duration::from_secs(10);

/// Session-pool counters, surfaced under `"server"` in the `stats` op.
#[derive(Default)]
struct ServerCounters {
    /// Sessions accepted over the server's lifetime.
    sessions: AtomicU64,
    /// Sessions currently open (a gauge: incremented at accept,
    /// decremented when the session thread returns).
    live_sessions: AtomicU64,
    /// Sessions disconnected because a response write sat blocked past
    /// the write deadline (stalled client).
    write_timeouts: AtomicU64,
    /// State-changing admin ops refused because the request's `token`
    /// did not match the configured `--admin-token`.
    admin_denied: AtomicU64,
}

impl ServerCounters {
    fn snapshot(&self) -> Json {
        let mut obj = JsonObj::new();
        obj.insert("sessions", Json::from(self.sessions.load(Ordering::Relaxed) as usize));
        obj.insert(
            "live_sessions",
            Json::from(self.live_sessions.load(Ordering::Relaxed) as usize),
        );
        obj.insert(
            "write_timeouts",
            Json::from(self.write_timeouts.load(Ordering::Relaxed) as usize),
        );
        obj.insert(
            "admin_denied",
            Json::from(self.admin_denied.load(Ordering::Relaxed) as usize),
        );
        Json::Obj(obj)
    }
}

/// A blocked-write error produced by the socket write deadline
/// (`SO_SNDTIMEO` surfaces as `WouldBlock` on Unix, `TimedOut` on
/// other platforms).
fn is_write_timeout(e: &std::io::Error) -> bool {
    matches!(e.kind(), std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut)
}

/// Every metric family the `metrics` op's exposition can emit.  The
/// golden test pins the exposition to exactly this set, and
/// `scripts/check_invariants.py` (rule E) requires every name here to
/// appear in the ARCHITECTURE.md metric inventory table.
pub const METRIC_NAMES: &[&str] = &[
    "bcnn_uptime_seconds",
    "bcnn_sessions_total",
    "bcnn_live_sessions",
    "bcnn_write_timeouts_total",
    "bcnn_admin_denied_total",
    "bcnn_stats_seq",
    "bcnn_trace_buffer_len",
    "bcnn_traces_dropped_total",
    "bcnn_journal_events_total",
    "bcnn_journal_dropped_total",
    "bcnn_model_loads_total",
    "bcnn_model_load_failures_total",
    "bcnn_verify_failures_total",
    "bcnn_rewrite_fallbacks_total",
    "bcnn_default_swaps_total",
    "bcnn_model_evictions_total",
    "bcnn_route_version",
    "bcnn_requests_submitted_total",
    "bcnn_requests_rejected_total",
    "bcnn_requests_completed_total",
    "bcnn_requests_failed_total",
    "bcnn_batches_total",
    "bcnn_mean_batch_size",
    "bcnn_streams_total",
    "bcnn_stream_frames_total",
    "bcnn_queue_depth",
    "bcnn_queue_capacity",
    "bcnn_latency_count",
    "bcnn_latency_us",
    "bcnn_scratch_arenas",
    "bcnn_scratch_peak_bytes",
    "bcnn_kernel_dispatch",
];

/// Append one `name{labels} value` exposition line.
fn push_sample(out: &mut String, name: &str, labels: &str, value: f64) {
    use std::fmt::Write as _;
    if labels.is_empty() {
        let _ = writeln!(out, "{name} {value}");
    } else {
        let _ = writeln!(out, "{name}{{{labels}}} {value}");
    }
}

/// The serving front end.
pub struct Server {
    registry: Arc<ModelRegistry>,
    classes: Vec<String>,
    synth_seed: u64,
    write_timeout: Option<Duration>,
    /// When set, the state-changing admin ops (`load_model`,
    /// `unload_model`, `set_default`) require a matching request
    /// `"token"`; read-only ops stay open.
    admin_token: Option<String>,
    counters: ServerCounters,
    /// Server start instant (`uptime_s` in `stats`, `bcnn_uptime_seconds`
    /// in the metrics exposition).
    started: Instant,
    /// Monotonic snapshot sequence: every `stats` reply carries the next
    /// number, so a scraper can order snapshots it collected out of band.
    stats_seq: AtomicU64,
    /// Deterministic 1-in-N sampler for classify-family requests
    /// (`serve --trace-sample N`; 0 = off, the zero-allocation default).
    sampler: TraceSampler,
    /// Ring buffer of captured traces, drained by the `trace_dump` op.
    traces: TraceStore,
}

impl Server {
    pub fn new(registry: Arc<ModelRegistry>, classes: Vec<String>) -> Self {
        // announce the XNOR microkernel serving this process — one
        // startup journal event, so recorded perf envelopes correlate
        // with the kernel that produced them
        registry.journal().log(event::KERNEL_DISPATCH, dispatch::current().name());
        Self {
            registry,
            classes,
            synth_seed: synth::DEFAULT_SEED,
            write_timeout: Some(DEFAULT_WRITE_TIMEOUT),
            admin_token: None,
            counters: ServerCounters::default(),
            started: Instant::now(),
            stats_seq: AtomicU64::new(0),
            sampler: TraceSampler::new(0),
            traces: TraceStore::new(TraceStore::DEFAULT_CAPACITY),
        }
    }

    /// Trace one in `every` classify-family requests (`0` disables
    /// sampling — the default; forced `"trace": true` requests are
    /// always captured regardless).
    pub fn with_trace_sample(mut self, every: u64) -> Self {
        self.sampler = TraceSampler::new(every);
        self
    }

    /// Override the per-session write deadline (`None` disables it —
    /// a stalled client then pins its session thread indefinitely).
    pub fn with_write_timeout(mut self, timeout: Option<Duration>) -> Self {
        self.write_timeout = timeout;
        self
    }

    /// Gate the state-changing admin ops behind a shared token
    /// (`serve --admin-token`); `None` (the default) leaves them open
    /// for trusted-network deployments.
    pub fn with_admin_token(mut self, token: Option<String>) -> Self {
        self.admin_token = token;
        self
    }

    /// `Some(rejection)` when an admin op's token does not satisfy the
    /// configured gate.  Counted so operators can see probe attempts in
    /// the `stats` op.
    ///
    /// The comparison is constant-time in the supplied token's bytes:
    /// the gate exists precisely so the port can face less-trusted
    /// networks, where an early-exit string compare would leak the
    /// token prefix through response latency.
    fn check_admin_token(&self, token: &Option<String>) -> Option<Response> {
        let expected = self.admin_token.as_deref()?;
        let supplied = token.as_deref().unwrap_or("");
        let mut diff = u8::from(supplied.len() != expected.len());
        for (a, b) in supplied.bytes().zip(expected.bytes().cycle()) {
            diff |= a ^ b;
        }
        if token.is_some() && diff == 0 {
            return None;
        }
        self.counters.admin_denied.fetch_add(1, Ordering::Relaxed);
        Some(Response::Error(
            "admin op requires a valid \"token\" (server started with --admin-token)"
                .to_string(),
        ))
    }

    /// The registry this server resolves models against (admin surface
    /// for embedding callers and tests).
    pub fn registry(&self) -> &Arc<ModelRegistry> {
        &self.registry
    }

    /// Handle one already-parsed request (also used by unit tests and the
    /// in-process CLI path — no socket required).
    ///
    /// `ClassifyBatchStream` is the one op that produces *several* frames
    /// for one request line, so it cannot be answered here; the TCP
    /// session routes it to [`Server::stream_batch`] instead, and
    /// single-response callers get a structured error.
    pub fn handle(&self, req: Request) -> Response {
        match req {
            Request::Ping => Response::Pong,
            Request::Variants => Response::Variants(self.registry.router().variants()),
            Request::Stats => {
                let mut obj = JsonObj::new();
                obj.insert("uptime_s", Json::from(self.started.elapsed().as_secs_f64()));
                obj.insert(
                    "seq",
                    Json::from(self.stats_seq.fetch_add(1, Ordering::Relaxed) as usize),
                );
                obj.insert("kernel", Json::from(dispatch::current().name()));
                obj.insert("lanes", self.registry.router().stats());
                obj.insert("registry", self.registry.counters_json());
                obj.insert("server", self.counters.snapshot());
                obj.insert("journal", self.registry.journal().to_json());
                Response::Stats(Json::Obj(obj))
            }
            Request::Classify { model, pixels, .. } => self.classify(&model, pixels, None),
            Request::ClassifyBatch { model, images } => self.classify_batch(&model, images),
            Request::ClassifyBatchStream { .. } => Response::Error(
                "classify_batch_stream emits multiple frames; use a streaming transport \
                 (or classify_batch for a single grouped response)"
                    .to_string(),
            ),
            Request::ClassifySynth { model, index } => {
                let sample = synth::render_vehicle(index, self.synth_seed);
                self.classify(&model, sample.image, None)
            }
            Request::LoadModel { name, version, token } => {
                if let Some(denied) = self.check_admin_token(&token) {
                    return denied;
                }
                match self.registry.load_model(&name, version) {
                    Ok(model) => Response::AdminAck { action: "load_model", model },
                    Err(e) => Response::Error(e.to_string()),
                }
            }
            Request::UnloadModel { name, version, token } => {
                if let Some(denied) = self.check_admin_token(&token) {
                    return denied;
                }
                match self.registry.unload_model(&name, version) {
                    Ok(model) => Response::AdminAck { action: "unload_model", model },
                    Err(e) => Response::Error(e.to_string()),
                }
            }
            Request::SetDefault { name, version, token } => {
                if let Some(denied) = self.check_admin_token(&token) {
                    return denied;
                }
                match self.registry.set_default(&name, version) {
                    Ok(model) => Response::AdminAck { action: "set_default", model },
                    Err(e) => Response::Error(e.to_string()),
                }
            }
            Request::ListModels => Response::Models {
                models: self.registry.list_models(),
                registry: self.registry.counters_json(),
                kernel: dispatch::current().name().to_string(),
            },
            Request::Metrics => Response::Metrics(self.render_metrics()),
            Request::TraceDump { model } => {
                let drained = self.traces.drain(model.as_deref());
                Response::Traces {
                    traces: Json::Arr(drained.iter().map(Trace::to_json).collect()),
                    dropped: self.traces.dropped(),
                }
            }
        }
    }

    /// [`Server::handle`] with a pre-started trace attached to the
    /// classify-family ops; other ops ignore the trace.  The returned
    /// `Classified` carries the completed trace back (the session layer
    /// decides whether it is echoed inline, stored, or both).
    fn handle_traced(&self, req: Request, trace: Option<Box<Trace>>) -> Response {
        match req {
            Request::Classify { model, pixels, .. } => self.classify(&model, pixels, trace),
            Request::ClassifySynth { model, index } => {
                let sample = synth::render_vehicle(index, self.synth_seed);
                self.classify(&model, sample.image, trace)
            }
            other => self.handle(other),
        }
    }

    /// Turn a completed coordinator response into a protocol response.
    /// `lane` is the resolved `name@version` that served the request —
    /// clients see exactly which version answered, which is what the
    /// hot-reload test asserts across a mid-flight swap.
    fn render(&self, lane: &str, resp: crate::coordinator::InferResponse) -> Response {
        if let Some(err) = resp.error {
            return Response::Error(err);
        }
        Response::Classified {
            model: lane.to_string(),
            class: resp.class,
            label: self
                .classes
                .get(resp.class)
                .cloned()
                .unwrap_or_else(|| "?".to_string()),
            logits: resp.logits,
            queue_us: resp.queue_time.as_nanos() as f64 / 1_000.0,
            exec_us: resp.exec_time.as_nanos() as f64 / 1_000.0,
            batch: resp.batch_size,
            trace: resp.trace,
        }
    }

    fn classify(&self, model: &str, pixels: Vec<f32>, trace: Option<Box<Trace>>) -> Response {
        let lane = match self.registry.resolve(model) {
            Ok(lane) => lane,
            Err(e) => return Response::Error(e.to_string()),
        };
        match self.registry.router().infer_blocking_traced(&lane, pixels, trace) {
            Ok(resp) => self.render(&lane, resp),
            Err(e) => Response::Error(e.to_string()),
        }
    }

    /// Submit every image back-to-back so the dynamic batcher can drain
    /// them into one batched backend call; errors stay per-image
    /// (`render` maps a failed `InferResponse` to `Response::Error`).
    /// The model reference resolves ONCE for the whole group, so every
    /// image of a batch is served by the same registry entry even if an
    /// admin swaps the default mid-request.
    fn classify_batch(&self, model: &str, images: Vec<Vec<f32>>) -> Response {
        let lane = match self.registry.resolve(model) {
            Ok(lane) => lane,
            // keep the per-image results shape for every failure class of
            // this op: a client indexing results[] by submitted image must
            // not see a bare top-level error for this one case
            Err(e) => {
                return Response::Batch(vec![Response::Error(e.to_string()); images.len()])
            }
        };
        let items = self
            .registry
            .router()
            .infer_blocking_batch(&lane, images)
            .into_iter()
            .map(|resp| self.render(&lane, resp))
            .collect();
        Response::Batch(items)
    }

    /// Build the `metrics` op's flat text exposition: one
    /// `name{labels} value` line per sample, families exactly
    /// [`METRIC_NAMES`].  Reading is lock-light — every source is an
    /// atomic, a leaf mutex, or an existing snapshot call.
    fn render_metrics(&self) -> String {
        let mut out = String::new();
        // server-wide gauges and counters
        push_sample(&mut out, "bcnn_uptime_seconds", "", self.started.elapsed().as_secs_f64());
        let c = &self.counters;
        push_sample(&mut out, "bcnn_sessions_total", "", c.sessions.load(Ordering::Relaxed) as f64);
        push_sample(
            &mut out,
            "bcnn_live_sessions",
            "",
            c.live_sessions.load(Ordering::Relaxed) as f64,
        );
        push_sample(
            &mut out,
            "bcnn_write_timeouts_total",
            "",
            c.write_timeouts.load(Ordering::Relaxed) as f64,
        );
        push_sample(
            &mut out,
            "bcnn_admin_denied_total",
            "",
            c.admin_denied.load(Ordering::Relaxed) as f64,
        );
        push_sample(&mut out, "bcnn_stats_seq", "", self.stats_seq.load(Ordering::Relaxed) as f64);
        push_sample(&mut out, "bcnn_trace_buffer_len", "", self.traces.len() as f64);
        push_sample(&mut out, "bcnn_traces_dropped_total", "", self.traces.dropped() as f64);
        let journal = self.registry.journal();
        push_sample(&mut out, "bcnn_journal_events_total", "", journal.total() as f64);
        push_sample(&mut out, "bcnn_journal_dropped_total", "", journal.dropped() as f64);
        // registry lifecycle counters + the route-snapshot version gauge
        let reg = self.registry.counters_json();
        let reg_counter = |key: &str| reg.get(key).and_then(|v| v.as_f64()).unwrap_or(0.0);
        push_sample(&mut out, "bcnn_model_loads_total", "", reg_counter("loads"));
        push_sample(&mut out, "bcnn_model_load_failures_total", "", reg_counter("load_failures"));
        push_sample(&mut out, "bcnn_verify_failures_total", "", reg_counter("verify_failures"));
        push_sample(
            &mut out,
            "bcnn_rewrite_fallbacks_total",
            "",
            reg_counter("rewrite_fallbacks"),
        );
        push_sample(&mut out, "bcnn_default_swaps_total", "", reg_counter("swaps"));
        push_sample(&mut out, "bcnn_model_evictions_total", "", reg_counter("evictions"));
        push_sample(&mut out, "bcnn_route_version", "", self.registry.route_version() as f64);
        // per-lane traffic, latency quantiles, queue depth, scratch pool
        let router = self.registry.router();
        for lane in router.variants() {
            let label = format!("lane=\"{lane}\"");
            if let Ok(m) = router.metrics(&lane) {
                let snap = m.snapshot();
                let field = |key: &str| snap.get(key).and_then(|v| v.as_f64()).unwrap_or(0.0);
                push_sample(&mut out, "bcnn_requests_submitted_total", &label, field("submitted"));
                push_sample(&mut out, "bcnn_requests_rejected_total", &label, field("rejected"));
                push_sample(&mut out, "bcnn_requests_completed_total", &label, field("completed"));
                push_sample(&mut out, "bcnn_requests_failed_total", &label, field("failed"));
                push_sample(&mut out, "bcnn_batches_total", &label, field("batches"));
                push_sample(&mut out, "bcnn_mean_batch_size", &label, field("mean_batch_size"));
                push_sample(&mut out, "bcnn_streams_total", &label, field("streams"));
                push_sample(&mut out, "bcnn_stream_frames_total", &label, field("stream_frames"));
                for stage in ["queue", "exec", "e2e"] {
                    let Ok(h) = snap.get(&format!("{stage}_us")) else { continue };
                    let hf = |key: &str| h.get(key).and_then(|v| v.as_f64()).unwrap_or(0.0);
                    push_sample(
                        &mut out,
                        "bcnn_latency_count",
                        &format!("{label},stage=\"{stage}\""),
                        hf("count"),
                    );
                    for q in ["p50", "p95", "p99"] {
                        push_sample(
                            &mut out,
                            "bcnn_latency_us",
                            &format!("{label},stage=\"{stage}\",quantile=\"{q}\""),
                            hf(q),
                        );
                    }
                }
            }
            if let Ok((depth, cap)) = router.queue_depth(&lane) {
                push_sample(&mut out, "bcnn_queue_depth", &label, depth as f64);
                push_sample(&mut out, "bcnn_queue_capacity", &label, cap as f64);
            }
            if let Ok(backend) = router.lane_backend(&lane) {
                if let Some(pool) = backend.pool_stats() {
                    push_sample(&mut out, "bcnn_scratch_arenas", &label, pool.arenas as f64);
                    for (class, bytes) in ["f32", "u32", "i32"].iter().zip(pool.peak_bytes) {
                        push_sample(
                            &mut out,
                            "bcnn_scratch_peak_bytes",
                            &format!("{label},class=\"{class}\""),
                            bytes as f64,
                        );
                    }
                }
            }
        }
        // the dispatched XNOR microkernel, as an info-style gauge: the
        // kernel name rides the label, the value is a constant 1
        push_sample(
            &mut out,
            "bcnn_kernel_dispatch",
            &format!("kernel=\"{}\"", dispatch::current().name()),
            1.0,
        );
        out
    }

    /// The `classify_batch_stream` engine: submit the whole group onto
    /// one shared response channel, then `emit` one framed response per
    /// image **as it completes** (completion order — with multi-executor
    /// lanes a fast image's frame goes out while a slow peer is still
    /// executing), finishing with a `stream_end` summary in submission
    /// order.
    ///
    /// `emit` returns `false` when the client is gone (failed write);
    /// the method then stops immediately — dropping the group receiver
    /// is safe, executors never block on a disconnected channel.
    /// Returns `false` iff an emit failed.
    ///
    /// Per-image failures all flow through the same frame shape with a
    /// real request id, whatever their origin: parse-layer rejects
    /// (non-finite pixels) arrive as `Err` images, bad payload sizes and
    /// queue backpressure fail at submission, and non-finite logits fail
    /// in the batcher.
    pub fn stream_batch(
        &self,
        model: &str,
        images: Vec<Result<Vec<f32>, String>>,
        emit: &mut dyn FnMut(&Response) -> bool,
    ) -> bool {
        // One path for every per-image frame, whatever layer produced the
        // body: record its outcome, count it, frame it, write it.
        fn emit_item(
            metrics: &Option<Arc<crate::coordinator::Metrics>>,
            ok_by_seq: &mut [Option<bool>],
            emit: &mut dyn FnMut(&Response) -> bool,
            seq: usize,
            id: u64,
            body: Response,
        ) -> bool {
            ok_by_seq[seq] = Some(!matches!(body, Response::Error(_)));
            let delivered = emit(&Response::StreamItem { seq, id, body: Box::new(body) });
            // count only frames actually written — a client hanging up
            // mid-stream must not inflate the stats op
            if delivered {
                if let Some(m) = metrics {
                    m.record_stream_frame();
                }
            }
            delivered
        }

        // resolve the model reference once for the whole group: every
        // frame of this stream is served by (and reports) one registry
        // entry, even when an admin swap lands mid-stream.  An
        // unresolvable reference fails per image — stream clients
        // consume per-image status anyway.
        let router = Arc::clone(self.registry.router());
        let (lane, images) = match self.registry.resolve(model) {
            Ok(lane) => (lane, images),
            Err(e) => {
                let msg = e.to_string();
                (String::new(), images.into_iter().map(|_| Err(msg.clone())).collect())
            }
        };
        let metrics = if lane.is_empty() { None } else { router.metrics(&lane).ok() };
        if let Some(m) = &metrics {
            m.record_stream();
        }
        // with an empty lane every image is an Err slot, so the group
        // never touches a queue — the frames below are pure failures
        let group = router.submit_group(&lane, images);
        let count = group.slots.len();
        let mut ok_by_seq: Vec<Option<bool>> = vec![None; count];
        // failure frames first for images that never reached the lane
        // (parse rejects, bad payloads, admission backpressure) — their
        // outcome is already known, the client shouldn't wait for it
        for (seq, slot) in group.slots.iter().enumerate() {
            if let Some(err) = &slot.error {
                let body = Response::Error(err.clone());
                if !emit_item(&metrics, &mut ok_by_seq, &mut *emit, seq, slot.id, body) {
                    return false;
                }
            }
        }
        // then one frame per admitted image, in completion order
        let seq_of_id: std::collections::HashMap<u64, usize> =
            group.slots.iter().enumerate().map(|(seq, s)| (s.id, seq)).collect();
        let mut pending = group.pending();
        while pending > 0 {
            match group.rx.recv() {
                Ok(resp) => {
                    pending -= 1;
                    // only this group's senders hold the channel, so the id
                    // always resolves; guard anyway — a session thread must
                    // never panic on traffic
                    let Some(&seq) = seq_of_id.get(&resp.id) else { continue };
                    let id = resp.id;
                    let body = self.render(&lane, resp);
                    if !emit_item(&metrics, &mut ok_by_seq, &mut *emit, seq, id, body) {
                        return false;
                    }
                }
                Err(_) => {
                    // the lane died mid-group: fail every still-pending
                    // image with its real id instead of hanging the client
                    for (seq, slot) in group.slots.iter().enumerate() {
                        if ok_by_seq[seq].is_none() {
                            let body = Response::Error(
                                "backend dropped the response channel".to_string(),
                            );
                            if !emit_item(&metrics, &mut ok_by_seq, &mut *emit, seq, slot.id, body)
                            {
                                return false;
                            }
                        }
                    }
                    break;
                }
            }
        }
        let results: Vec<StreamStatus> = group
            .slots
            .iter()
            .enumerate()
            .map(|(seq, slot)| StreamStatus {
                seq,
                id: slot.id,
                ok: ok_by_seq[seq].unwrap_or(false),
            })
            .collect();
        let completed = results.iter().filter(|s| s.ok).count();
        // the terminal summary names the serving entry like every
        // per-image frame does (empty when the reference never resolved)
        let end = Response::StreamEnd {
            model: lane,
            count,
            completed,
            failed: count - completed,
            results,
        };
        emit(&end)
    }

    /// Write one response line.  Returns `false` when the session must
    /// end; a write that sat blocked past the per-session deadline
    /// (stalled client) is counted before the disconnect.
    fn write_frame(&self, writer: &mut TcpStream, resp: &Response) -> bool {
        let mut out = resp.to_json_line();
        out.push('\n');
        match writer.write_all(out.as_bytes()) {
            Ok(()) => true,
            Err(e) => {
                if is_write_timeout(&e) {
                    self.counters.write_timeouts.fetch_add(1, Ordering::Relaxed);
                    self.registry.journal().log(
                        event::WRITE_TIMEOUT,
                        "session disconnected: response write exceeded the deadline",
                    );
                }
                false
            }
        }
    }

    fn session(&self, stream: TcpStream) {
        self.counters.sessions.fetch_add(1, Ordering::Relaxed);
        self.counters.live_sessions.fetch_add(1, Ordering::Relaxed);
        self.session_loop(stream);
        self.counters.live_sessions.fetch_sub(1, Ordering::Relaxed);
    }

    fn session_loop(&self, stream: TcpStream) {
        // the write deadline bounds how long a stalled client can pin
        // this session thread (docs/PROTOCOL.md "Backpressure"); reads
        // stay deadline-free — an idle-but-healthy session is fine
        let _ = stream.set_write_timeout(self.write_timeout);
        let mut writer = match stream.try_clone() {
            Ok(w) => w,
            Err(_) => return,
        };
        let mut reader = BufReader::new(stream);
        let mut buf = Vec::new();
        loop {
            // completed trace awaiting its terminal "written" span; pushed
            // to the store only after the response actually went out
            let mut stored_trace: Option<Box<Trace>> = None;
            let resp = match read_line_bounded(&mut reader, &mut buf) {
                Ok(None) | Err(_) => break, // EOF or dead socket
                Ok(Some(Err(()))) => {
                    Response::Error(format!("request line exceeds {MAX_LINE_BYTES} bytes"))
                }
                Ok(Some(Ok(()))) => {
                    // trace zero point sits BEFORE parsing, so the
                    // "parsed" span prices the parse itself
                    let received = Instant::now();
                    // invalid UTF-8 (e.g. binary garbage) must produce a
                    // protocol error, not kill the session
                    let parsed = {
                        let line = String::from_utf8_lossy(&buf);
                        if line.trim().is_empty() {
                            continue;
                        }
                        Request::parse(&line)
                    };
                    match parsed {
                        // the one multi-frame op: write each frame as it
                        // completes.  Backpressure is structural — while a
                        // slow client stalls a write here, completed
                        // responses buffer in the group's channel, which
                        // holds at most MAX_BATCH_IMAGES entries for this
                        // session; the lane's executors never block on it,
                        // and the write deadline bounds the stall itself.
                        Ok(Request::ClassifyBatchStream { model, images }) => {
                            let alive = self.stream_batch(&model, images, &mut |frame| {
                                self.write_frame(&mut writer, frame)
                            });
                            if !alive {
                                break; // client gone (or stalled) mid-stream
                            }
                            buf.shrink_to(64 * 1024);
                            continue;
                        }
                        Ok(req) => {
                            let forced = matches!(req, Request::Classify { trace: true, .. });
                            let eligible = matches!(
                                req,
                                Request::Classify { .. } | Request::ClassifySynth { .. }
                            );
                            if forced
                                || (eligible && self.sampler.enabled() && self.sampler.sample())
                            {
                                let mut t = Box::new(Trace::begin_at(received));
                                t.mark("parsed");
                                let mut resp = self.handle_traced(req, Some(t));
                                if let Response::Classified { trace, .. } = &mut resp {
                                    // sampled-only traces go to the store
                                    // without bloating the response; forced
                                    // ones are echoed inline AND stored
                                    stored_trace =
                                        if forced { trace.clone() } else { trace.take() };
                                }
                                resp
                            } else {
                                self.handle(req)
                            }
                        }
                        Err(e) => Response::Error(e),
                    }
                }
            };
            if !self.write_frame(&mut writer, &resp) {
                break;
            }
            if let Some(mut t) = stored_trace.take() {
                // the stored copy alone carries the "written" span — the
                // inline echo was serialized before the write finished
                t.mark("written");
                self.traces.push(*t);
            }
            // a maximal request mustn't pin tens of MB for an idle session
            buf.shrink_to(64 * 1024);
        }
    }

    /// Bind and serve until `stop` flips (or forever).  Returns the bound
    /// address once listening.
    pub fn serve(
        self: Arc<Self>,
        addr: &str,
        threads: usize,
        stop: Arc<AtomicBool>,
    ) -> std::io::Result<std::net::SocketAddr> {
        let listener = TcpListener::bind(addr)?;
        let local = listener.local_addr()?;
        listener.set_nonblocking(true)?;
        let pool = ThreadPool::new(threads, "server");
        std::thread::Builder::new().name("acceptor".into()).spawn(move || {
            loop {
                if stop.load(Ordering::Relaxed) {
                    break;
                }
                match listener.accept() {
                    Ok((stream, _)) => {
                        let _ = stream.set_nonblocking(false);
                        let me = Arc::clone(&self);
                        pool.execute(move || me.session(stream));
                    }
                    Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                        std::thread::sleep(std::time::Duration::from_millis(5));
                    }
                    Err(_) => break,
                }
            }
        })?;
        Ok(local)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bnn::network::tests_support::synth_bcnn_network;
    use crate::coordinator::{EngineBackend, InferBackend};
    use crate::input::binarize::Scheme;

    fn test_server() -> Arc<Server> {
        let registry = ModelRegistry::builder().build();
        let be: Arc<dyn InferBackend> =
            Arc::new(EngineBackend::bcnn(synth_bcnn_network(Scheme::Rgb, 5), 2));
        registry.publish_backend("bcnn_rgb", 1, "bcnn", "rgb", None, be).unwrap();
        Arc::new(Server::new(
            registry,
            vec!["bus".into(), "normal".into(), "truck".into(), "van".into()],
        ))
    }

    #[test]
    fn handle_ping_and_variants() {
        let s = test_server();
        assert!(matches!(s.handle(Request::Ping), Response::Pong));
        match s.handle(Request::Variants) {
            Response::Variants(v) => assert_eq!(v, vec!["bcnn_rgb@1"]),
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn handle_classify_synth() {
        let s = test_server();
        match s.handle(Request::ClassifySynth { model: "".into(), index: 3 }) {
            Response::Classified { model, class, label, logits, batch, .. } => {
                assert_eq!(model, "bcnn_rgb@1", "response reports the serving entry");
                assert!(class < 4);
                assert!(["bus", "normal", "truck", "van"].contains(&label.as_str()));
                assert_eq!(logits.len(), 4);
                assert_eq!(batch, 1);
            }
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn handle_admin_lifecycle_in_process() {
        let s = test_server();
        // a second version arrives and is swapped in
        let be: Arc<dyn InferBackend> =
            Arc::new(EngineBackend::bcnn(synth_bcnn_network(Scheme::Rgb, 6), 2));
        s.registry().publish_backend("bcnn_rgb", 2, "bcnn", "rgb", None, be).unwrap();
        match s.handle(Request::SetDefault {
            name: "bcnn_rgb".into(),
            version: Some(2),
            token: None,
        }) {
            Response::AdminAck { action, model } => {
                assert_eq!(action, "set_default");
                assert_eq!(model, "bcnn_rgb@2");
            }
            other => panic!("{other:?}"),
        }
        match s.handle(Request::ClassifySynth { model: "".into(), index: 0 }) {
            Response::Classified { model, .. } => assert_eq!(model, "bcnn_rgb@2"),
            other => panic!("{other:?}"),
        }
        // pinned references still reach the old version until unload
        match s.handle(Request::ClassifySynth { model: "bcnn_rgb@1".into(), index: 0 }) {
            Response::Classified { model, .. } => assert_eq!(model, "bcnn_rgb@1"),
            other => panic!("{other:?}"),
        }
        match s.handle(Request::UnloadModel { name: "bcnn_rgb".into(), version: 1, token: None }) {
            Response::AdminAck { action, model } => {
                assert_eq!(action, "unload_model");
                assert_eq!(model, "bcnn_rgb@1");
            }
            other => panic!("{other:?}"),
        }
        match s.handle(Request::ClassifySynth { model: "bcnn_rgb@1".into(), index: 0 }) {
            Response::Error(e) => assert!(e.contains("unknown model"), "{e}"),
            other => panic!("{other:?}"),
        }
        match s.handle(Request::ListModels) {
            Response::Models { models, registry, .. } => {
                let rows = models.as_arr().unwrap();
                assert_eq!(rows.len(), 1);
                assert_eq!(rows[0].get("model").unwrap().as_str().unwrap(), "bcnn_rgb@2");
                assert_eq!(registry.get("evictions").unwrap().as_usize().unwrap(), 1);
                assert_eq!(registry.get("swaps").unwrap().as_usize().unwrap(), 1);
            }
            other => panic!("{other:?}"),
        }
        // load_model without a models dir is a structured error
        match s.handle(Request::LoadModel { name: "bcnn_rgb".into(), version: 3, token: None }) {
            Response::Error(e) => assert!(e.contains("--models"), "{e}"),
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn admin_token_gates_state_changing_ops_only() {
        let registry = ModelRegistry::builder().build();
        let be: Arc<dyn InferBackend> =
            Arc::new(EngineBackend::bcnn(synth_bcnn_network(Scheme::Rgb, 7), 2));
        registry.publish_backend("m", 1, "bcnn", "rgb", None, be).unwrap();
        let be2: Arc<dyn InferBackend> =
            Arc::new(EngineBackend::bcnn(synth_bcnn_network(Scheme::Rgb, 8), 2));
        registry.publish_backend("m", 2, "bcnn", "rgb", None, be2).unwrap();
        let s = Server::new(registry, vec!["bus".into()])
            .with_admin_token(Some("s3cret".to_string()));

        // missing, wrong, prefix, and cyclic-extension tokens are all
        // refused and counted (the compare is constant-time length-aware:
        // "s3crets3cret..." must not pass by cycling the real token)
        for token in [
            None,
            Some("wrong".to_string()),
            Some("s3cre".to_string()),
            Some("s3cret".repeat(44)),
        ] {
            match s.handle(Request::SetDefault { name: "m".into(), version: Some(2), token }) {
                Response::Error(e) => assert!(e.contains("token"), "{e}"),
                other => panic!("{other:?}"),
            }
        }
        // ...without the swap happening
        assert_eq!(s.registry().resolve("m").unwrap(), "m@1");
        // the right token goes through
        match s.handle(Request::SetDefault {
            name: "m".into(),
            version: Some(2),
            token: Some("s3cret".to_string()),
        }) {
            Response::AdminAck { model, .. } => assert_eq!(model, "m@2"),
            other => panic!("{other:?}"),
        }
        // read-only ops never need the token
        assert!(matches!(s.handle(Request::ListModels), Response::Models { .. }));
        assert!(matches!(s.handle(Request::Stats), Response::Stats(_)));
        // every rejection is visible in the stats op
        match s.handle(Request::Stats) {
            Response::Stats(stats) => {
                let denied = stats
                    .get("server")
                    .unwrap()
                    .get("admin_denied")
                    .unwrap()
                    .as_usize()
                    .unwrap();
                assert_eq!(denied, 4);
            }
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn no_admin_token_leaves_admin_ops_open() {
        let s = test_server();
        // the PR 4 default posture is preserved: token absent, ops work
        let be: Arc<dyn InferBackend> =
            Arc::new(EngineBackend::bcnn(synth_bcnn_network(Scheme::Rgb, 9), 2));
        s.registry().publish_backend("bcnn_rgb", 2, "bcnn", "rgb", None, be).unwrap();
        match s.handle(Request::SetDefault {
            name: "bcnn_rgb".into(),
            version: Some(2),
            token: None,
        }) {
            Response::AdminAck { .. } => {}
            other => panic!("{other:?}"),
        }
        // a stray token on an ungated server is simply ignored
        match s.handle(Request::SetDefault {
            name: "bcnn_rgb".into(),
            version: Some(1),
            token: Some("whatever".to_string()),
        }) {
            Response::AdminAck { .. } => {}
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn stats_compose_lanes_registry_and_server_sections() {
        let s = test_server();
        s.handle(Request::ClassifySynth { model: "".into(), index: 1 });
        match s.handle(Request::Stats) {
            Response::Stats(stats) => {
                let lanes = stats.get("lanes").unwrap();
                let lane = lanes.get("bcnn_rgb@1").unwrap();
                assert_eq!(lane.get("completed").unwrap().as_usize().unwrap(), 1);
                assert!(stats.get("registry").unwrap().get("loads").is_ok());
                let server = stats.get("server").unwrap();
                assert_eq!(server.get("write_timeouts").unwrap().as_usize().unwrap(), 0);
            }
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn stats_carry_uptime_seq_and_journal() {
        let s = test_server();
        match s.handle(Request::Stats) {
            Response::Stats(stats) => {
                assert!(stats.get("uptime_s").unwrap().as_f64().unwrap() >= 0.0);
                assert_eq!(stats.get("seq").unwrap().as_usize().unwrap(), 0);
                let journal = stats.get("journal").unwrap();
                // the publication of bcnn_rgb@1 is already journaled
                assert!(journal.get("next_seq").unwrap().as_f64().unwrap() >= 1.0);
                let events = journal.get("events").unwrap().as_arr().unwrap();
                assert!(events
                    .iter()
                    .any(|e| e.get("kind").unwrap().as_str().unwrap() == "model_load"));
            }
            other => panic!("{other:?}"),
        }
        // the snapshot sequence is monotonic across stats calls
        match s.handle(Request::Stats) {
            Response::Stats(stats) => {
                assert_eq!(stats.get("seq").unwrap().as_usize().unwrap(), 1);
            }
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn metrics_exposition_is_golden_against_metric_names() {
        let s = test_server();
        s.handle(Request::ClassifySynth { model: "".into(), index: 1 });
        let text = match s.handle(Request::Metrics) {
            Response::Metrics(text) => text,
            other => panic!("{other:?}"),
        };
        // every emitted family is declared...
        let mut seen = std::collections::BTreeSet::new();
        for line in text.lines() {
            let name = line.split(|c: char| c == '{' || c == ' ').next().unwrap();
            assert!(METRIC_NAMES.contains(&name), "undeclared metric family {name:?}");
            seen.insert(name.to_string());
        }
        // ...and every declared family is emitted
        for name in METRIC_NAMES {
            assert!(seen.contains(*name), "declared family {name} missing from exposition");
        }
        // spot-check shapes: an exact counter line and a labelled quantile
        assert!(
            text.contains("bcnn_requests_completed_total{lane=\"bcnn_rgb@1\"} 1"),
            "{text}"
        );
        assert!(
            text.contains("bcnn_latency_us{lane=\"bcnn_rgb@1\",stage=\"e2e\",quantile=\"p95\"}"),
            "{text}"
        );
        assert!(text.contains("bcnn_scratch_peak_bytes{lane=\"bcnn_rgb@1\",class=\"u32\"}"));
    }

    #[test]
    fn dispatched_kernel_is_reported_on_every_surface() {
        // with BCNN_KERNEL unset, the detected kernel must be visible
        // in stats, list_models, the metrics exposition, and the
        // startup journal event — and an override must flow through
        // all four (env serialized like the corrupt-plan hooks)
        let env = crate::platform::dispatch::kernel_env_guard();
        std::env::remove_var(dispatch::KERNEL_ENV);
        let detected = dispatch::detect().name();
        let s = test_server();
        match s.handle(Request::Stats) {
            Response::Stats(stats) => {
                assert_eq!(stats.get("kernel").unwrap().as_str().unwrap(), detected);
                let journal = stats.get("journal").unwrap();
                let events = journal.get("events").unwrap().as_arr().unwrap();
                assert!(
                    events.iter().any(|e| {
                        e.get("kind").unwrap().as_str().unwrap() == event::KERNEL_DISPATCH
                            && e.get("detail").unwrap().as_str().unwrap() == detected
                    }),
                    "kernel_dispatch journal event missing: {journal:?}"
                );
            }
            other => panic!("{other:?}"),
        }
        match s.handle(Request::ListModels) {
            Response::Models { kernel, .. } => assert_eq!(kernel, detected),
            other => panic!("{other:?}"),
        }
        match s.handle(Request::Metrics) {
            Response::Metrics(text) => assert!(
                text.contains(&format!("bcnn_kernel_dispatch{{kernel=\"{detected}\"}} 1")),
                "{text}"
            ),
            other => panic!("{other:?}"),
        }
        // a forced override reaches the same surfaces live
        std::env::set_var(dispatch::KERNEL_ENV, "scalar");
        match s.handle(Request::Stats) {
            Response::Stats(stats) => {
                assert_eq!(stats.get("kernel").unwrap().as_str().unwrap(), "scalar");
            }
            other => panic!("{other:?}"),
        }
        match s.handle(Request::Metrics) {
            Response::Metrics(text) => {
                assert!(text.contains("bcnn_kernel_dispatch{kernel=\"scalar\"} 1"), "{text}");
            }
            other => panic!("{other:?}"),
        }
        std::env::remove_var(dispatch::KERNEL_ENV);
        drop(env);
    }

    #[test]
    fn handle_traced_returns_a_full_monotone_span_timeline() {
        let s = test_server();
        let mut t = Box::new(crate::util::trace::Trace::begin());
        t.mark("parsed");
        let pixels = vec![0.5f32; 96 * 96 * 3];
        let resp =
            s.handle_traced(Request::Classify { model: "".into(), pixels, trace: true }, Some(t));
        match resp {
            Response::Classified { trace: Some(t), .. } => {
                assert_eq!(t.model, "bcnn_rgb@1");
                assert!(t.id > 0, "router assigned a real request id");
                let labels: Vec<&str> = t.spans().iter().map(|(l, _)| l.as_str()).collect();
                assert_eq!(&labels[..4], &["parsed", "admitted", "enqueued", "batch_formed"]);
                assert_eq!(*labels.last().unwrap(), "logits");
                assert!(labels.iter().any(|l| l.starts_with("exec:")), "{labels:?}");
                let offs: Vec<u64> = t.spans().iter().map(|(_, o)| *o).collect();
                assert!(offs.windows(2).all(|w| w[0] <= w[1]), "{offs:?}");
            }
            other => panic!("{other:?}"),
        }
        // the untraced path answers with no trace attached
        let pixels = vec![0.5f32; 96 * 96 * 3];
        match s.handle(Request::Classify { model: "".into(), pixels, trace: false }) {
            Response::Classified { trace, .. } => assert!(trace.is_none()),
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn trace_dump_on_an_idle_server_is_empty() {
        let s = test_server();
        match s.handle(Request::TraceDump { model: None }) {
            Response::Traces { traces, dropped } => {
                assert_eq!(traces.as_arr().unwrap().len(), 0);
                assert_eq!(dropped, 0);
            }
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn write_timeout_error_kinds_are_classified() {
        assert!(is_write_timeout(&std::io::Error::from(std::io::ErrorKind::WouldBlock)));
        assert!(is_write_timeout(&std::io::Error::from(std::io::ErrorKind::TimedOut)));
        assert!(!is_write_timeout(&std::io::Error::from(std::io::ErrorKind::BrokenPipe)));
    }

    #[test]
    fn handle_bad_model() {
        let s = test_server();
        match s.handle(Request::ClassifySynth { model: "bogus".into(), index: 0 }) {
            Response::Error(e) => assert!(e.contains("bcnn_rgb")),
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn handle_classify_batch_mixed_validity() {
        let s = test_server();
        let good = vec![0.5f32; 96 * 96 * 3];
        let bad = vec![0.5f32; 10]; // wrong payload size -> per-image error
        match s.handle(Request::ClassifyBatch {
            model: "".into(),
            images: vec![good.clone(), bad, good],
        }) {
            Response::Batch(items) => {
                assert_eq!(items.len(), 3);
                assert!(matches!(items[0], Response::Classified { .. }));
                assert!(matches!(items[1], Response::Error(_)));
                assert!(matches!(items[2], Response::Classified { .. }));
            }
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn classify_batch_unknown_model_keeps_per_image_results_shape() {
        let s = test_server();
        let good = vec![0.5f32; 96 * 96 * 3];
        match s.handle(Request::ClassifyBatch {
            model: "ghost".into(),
            images: vec![good.clone(), good],
        }) {
            Response::Batch(items) => {
                assert_eq!(items.len(), 2, "one entry per submitted image");
                for item in items {
                    match item {
                        Response::Error(e) => assert!(e.contains("unknown model"), "{e}"),
                        other => panic!("{other:?}"),
                    }
                }
            }
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn stream_batch_emits_per_image_frames_and_summary() {
        let s = test_server();
        let good = vec![0.5f32; 96 * 96 * 3];
        let frames = {
            let mut frames: Vec<Response> = Vec::new();
            let alive = s.stream_batch(
                "",
                vec![
                    Ok(good.clone()),
                    Err("non-finite pixel value".to_string()), // parse reject
                    Ok(vec![0.5f32; 10]),                      // bad payload
                ],
                &mut |frame| {
                    frames.push(frame.clone());
                    true
                },
            );
            assert!(alive);
            frames
        };
        assert_eq!(frames.len(), 4, "3 item frames + stream_end");
        // the two known-bad images fail first (no reason to wait), the
        // good image's frame follows on completion
        let mut ids = Vec::new();
        for frame in &frames[..3] {
            match frame {
                Response::StreamItem { id, body, seq } => {
                    ids.push(*id);
                    match (*seq, &**body) {
                        (0, Response::Classified { .. }) => {}
                        (1 | 2, Response::Error(_)) => {}
                        other => panic!("unexpected frame {other:?}"),
                    }
                }
                other => panic!("expected StreamItem, got {other:?}"),
            }
        }
        // real, distinct request ids on every frame — failures included
        ids.sort_unstable();
        ids.dedup();
        assert_eq!(ids.len(), 3);
        assert!(ids.iter().all(|&id| id != 0));
        match &frames[3] {
            Response::StreamEnd { model, count, completed, failed, results } => {
                assert_eq!(model, "bcnn_rgb@1", "summary names the serving entry");
                assert_eq!((*count, *completed, *failed), (3, 1, 2));
                let seqs: Vec<usize> = results.iter().map(|r| r.seq).collect();
                assert_eq!(seqs, vec![0, 1, 2], "summary is in submission order");
                assert!(results[0].ok && !results[1].ok && !results[2].ok);
            }
            other => panic!("expected StreamEnd, got {other:?}"),
        }
        // the lane's stats op records the stream session and its frames
        let lane = s.registry().resolve("").unwrap();
        let snap = s.registry().router().metrics(&lane).unwrap().snapshot();
        assert_eq!(snap.get("streams").unwrap().as_usize().unwrap(), 1);
        assert_eq!(snap.get("stream_frames").unwrap().as_usize().unwrap(), 3);
    }

    #[test]
    fn stream_batch_stops_when_client_goes_away() {
        let s = test_server();
        let good = vec![0.5f32; 96 * 96 * 3];
        let mut emitted = 0;
        let alive = s.stream_batch(
            "",
            vec![Ok(good.clone()), Ok(good)],
            &mut |_| {
                emitted += 1;
                false // client hung up on the first write
            },
        );
        assert!(!alive);
        assert_eq!(emitted, 1, "must stop emitting after a failed write");
    }

    #[test]
    fn handle_rejects_stream_op_on_single_response_path() {
        let s = test_server();
        match s.handle(Request::ClassifyBatchStream { model: "".into(), images: vec![] }) {
            Response::Error(e) => assert!(e.contains("streaming"), "{e}"),
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn read_line_bounded_handles_eof_and_split_lines() {
        let mut r = std::io::BufReader::new(&b"abc\ndef"[..]);
        let mut buf = Vec::new();
        assert_eq!(read_line_bounded(&mut r, &mut buf).unwrap(), Some(Ok(())));
        assert_eq!(buf, b"abc");
        assert_eq!(read_line_bounded(&mut r, &mut buf).unwrap(), Some(Ok(())));
        assert_eq!(buf, b"def"); // unterminated final line still delivered
        assert_eq!(read_line_bounded(&mut r, &mut buf).unwrap(), None);
    }

    #[test]
    fn tcp_end_to_end() {
        let s = test_server();
        let stop = Arc::new(AtomicBool::new(false));
        let addr = Arc::clone(&s).serve("127.0.0.1:0", 2, Arc::clone(&stop)).unwrap();
        let mut conn = TcpStream::connect(addr).unwrap();
        conn.write_all(b"{\"op\":\"classify_synth\",\"index\":1}\n{\"op\":\"stats\"}\n")
            .unwrap();
        let mut reader = BufReader::new(conn.try_clone().unwrap());
        let mut line = String::new();
        reader.read_line(&mut line).unwrap();
        assert!(line.contains("\"ok\": true") || line.contains("\"ok\":true"), "{line}");
        assert!(line.contains("label"));
        line.clear();
        reader.read_line(&mut line).unwrap();
        assert!(line.contains("stats"));
        stop.store(true, Ordering::Relaxed);
    }

    #[test]
    fn tcp_load_with_refused_rewrite_serves_the_fallback_plan() {
        // end-to-end over a real socket: an admin load whose fusion
        // rewrite is refused by the equivalence checker must still
        // publish (serving the verified unoptimized plan), answer
        // classify requests without dropping any, and surface the
        // fallback in list_models and stats
        use crate::bnn::network::tests_support::synth_bcnn_tf;
        use crate::registry::{corrupt_env_guard, fnv1a64, format_checksum};
        let dir = std::env::temp_dir()
            .join(format!("bcnn-tcp-rwfall-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let tf = synth_bcnn_tf(Scheme::Rgb, 700);
        tf.save(dir.join("fb.bcnt")).unwrap();
        let sum = format_checksum(fnv1a64(&std::fs::read(dir.join("fb.bcnt")).unwrap()));
        let manifest = format!(
            r#"{{"models": [
  {{"name": "fb", "version": 1, "kind": "bcnn", "scheme": "rgb",
    "weights_file": "fb.bcnt", "checksum": "{sum}"}}
]}}"#
        );
        std::fs::write(dir.join("registry.json"), manifest).unwrap();
        let registry = ModelRegistry::builder()
            .queue_capacity(64)
            .engine_threads(1)
            .models_dir(&dir)
            .build();
        let s = Arc::new(Server::new(
            registry,
            vec!["bus".into(), "normal".into(), "truck".into(), "van".into()],
        ));
        let stop = Arc::new(AtomicBool::new(false));
        let addr = Arc::clone(&s).serve("127.0.0.1:0", 2, Arc::clone(&stop)).unwrap();
        let mut conn = TcpStream::connect(addr).unwrap();
        let mut reader = BufReader::new(conn.try_clone().unwrap());
        let mut line = String::new();
        // arm the rewrite fault hook for the duration of the load (the
        // read_line blocks until the server finished handling it)
        let env = corrupt_env_guard();
        std::env::set_var("BCNN_TEST_CORRUPT_REWRITE", "fb:pad-bit-class-change");
        conn.write_all(b"{\"op\":\"load_model\",\"name\":\"fb\",\"version\":1}\n")
            .unwrap();
        reader.read_line(&mut line).unwrap();
        std::env::remove_var("BCNN_TEST_CORRUPT_REWRITE");
        drop(env);
        assert!(line.contains("load_model") && line.contains("fb@1"), "{line}");
        // the fallback entry answers every classify request
        for i in 0..3 {
            line.clear();
            let req = format!("{{\"op\":\"classify_synth\",\"model\":\"fb@1\",\"index\":{i}}}\n");
            conn.write_all(req.as_bytes()).unwrap();
            reader.read_line(&mut line).unwrap();
            assert!(line.contains("label"), "{line}");
        }
        // the refusal is operator-visible end to end
        line.clear();
        conn.write_all(b"{\"op\":\"list_models\"}\n").unwrap();
        reader.read_line(&mut line).unwrap();
        assert!(line.contains("fallback:equiv:"), "{line}");
        line.clear();
        conn.write_all(b"{\"op\":\"stats\"}\n").unwrap();
        reader.read_line(&mut line).unwrap();
        assert!(line.contains("\"rewrite_fallbacks\": 1"), "{line}");
        stop.store(true, Ordering::Relaxed);
    }
}
