//! TCP listener: one line-JSON session per connection, handled on a
//! fixed thread pool, requests routed through the coordinator.

use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;

use super::protocol::{Request, Response, StreamStatus};
use crate::coordinator::Router;
use crate::dataset::synth;
use crate::util::threadpool::ThreadPool;

/// Hard cap on one protocol line.  The largest legitimate request is a
/// `classify_batch` of `protocol::MAX_BATCH_IMAGES` (= 64) images; at a
/// worst-case ~20 text bytes per float (full f64 precision plus comma),
/// 64 × 27648 floats ≈ 36 MB of JSON, so 64 MiB leaves real headroom.
/// Anything beyond this is a hostile or broken client and gets a
/// structured error instead of an unbounded allocation.
pub const MAX_LINE_BYTES: usize = 64 * 1024 * 1024;

/// Read one `\n`-terminated line with a byte budget.
///
/// Returns `Ok(None)` at clean EOF, `Ok(Some(Err(())))` when the line
/// exceeded `MAX_LINE_BYTES` (the oversized tail is drained so the
/// session can continue), and IO errors otherwise.
fn read_line_bounded(
    reader: &mut impl BufRead,
    buf: &mut Vec<u8>,
) -> std::io::Result<Option<Result<(), ()>>> {
    buf.clear();
    let mut oversized = false;
    loop {
        let chunk = reader.fill_buf()?;
        if chunk.is_empty() {
            // EOF: a final unterminated line still counts as a line
            return Ok(match (oversized, buf.is_empty()) {
                (true, _) => Some(Err(())),
                (false, true) => None,
                (false, false) => Some(Ok(())),
            });
        }
        if let Some(nl) = chunk.iter().position(|&b| b == b'\n') {
            if !oversized && buf.len() + nl <= MAX_LINE_BYTES {
                buf.extend_from_slice(&chunk[..nl]);
            } else {
                oversized = true;
            }
            reader.consume(nl + 1);
            return Ok(Some(if oversized { Err(()) } else { Ok(()) }));
        }
        let take = chunk.len();
        if !oversized && buf.len() + take <= MAX_LINE_BYTES {
            buf.extend_from_slice(chunk);
        } else {
            oversized = true;
            buf.clear(); // stop buffering a hostile line
        }
        reader.consume(take);
    }
}

/// The serving front end.
pub struct Server {
    router: Arc<Router>,
    classes: Vec<String>,
    synth_seed: u64,
}

impl Server {
    pub fn new(router: Arc<Router>, classes: Vec<String>) -> Self {
        Self { router, classes, synth_seed: synth::DEFAULT_SEED }
    }

    /// Handle one already-parsed request (also used by unit tests and the
    /// in-process CLI path — no socket required).
    ///
    /// `ClassifyBatchStream` is the one op that produces *several* frames
    /// for one request line, so it cannot be answered here; the TCP
    /// session routes it to [`Server::stream_batch`] instead, and
    /// single-response callers get a structured error.
    pub fn handle(&self, req: Request) -> Response {
        match req {
            Request::Ping => Response::Pong,
            Request::Variants => Response::Variants(self.router.variants()),
            Request::Stats => Response::Stats(self.router.stats()),
            Request::Classify { model, pixels } => self.classify(&model, pixels),
            Request::ClassifyBatch { model, images } => self.classify_batch(&model, images),
            Request::ClassifyBatchStream { .. } => Response::Error(
                "classify_batch_stream emits multiple frames; use a streaming transport \
                 (or classify_batch for a single grouped response)"
                    .to_string(),
            ),
            Request::ClassifySynth { model, index } => {
                let sample = synth::render_vehicle(index, self.synth_seed);
                self.classify(&model, sample.image)
            }
        }
    }

    /// Turn a completed coordinator response into a protocol response.
    fn render(&self, resp: crate::coordinator::InferResponse) -> Response {
        if let Some(err) = resp.error {
            return Response::Error(err);
        }
        Response::Classified {
            class: resp.class,
            label: self
                .classes
                .get(resp.class)
                .cloned()
                .unwrap_or_else(|| "?".to_string()),
            logits: resp.logits,
            queue_us: resp.queue_time.as_nanos() as f64 / 1_000.0,
            exec_us: resp.exec_time.as_nanos() as f64 / 1_000.0,
            batch: resp.batch_size,
        }
    }

    fn classify(&self, model: &str, pixels: Vec<f32>) -> Response {
        match self.router.infer_blocking(model, pixels) {
            Ok(resp) => self.render(resp),
            Err(e) => Response::Error(e.to_string()),
        }
    }

    /// Submit every image back-to-back so the dynamic batcher can drain
    /// them into one batched backend call; errors stay per-image
    /// (`render` maps a failed `InferResponse` to `Response::Error`).
    fn classify_batch(&self, model: &str, images: Vec<Vec<f32>>) -> Response {
        let items = self
            .router
            .infer_blocking_batch(model, images)
            .into_iter()
            .map(|resp| self.render(resp))
            .collect();
        Response::Batch(items)
    }

    /// The `classify_batch_stream` engine: submit the whole group onto
    /// one shared response channel, then `emit` one framed response per
    /// image **as it completes** (completion order — with multi-executor
    /// lanes a fast image's frame goes out while a slow peer is still
    /// executing), finishing with a `stream_end` summary in submission
    /// order.
    ///
    /// `emit` returns `false` when the client is gone (failed write);
    /// the method then stops immediately — dropping the group receiver
    /// is safe, executors never block on a disconnected channel.
    /// Returns `false` iff an emit failed.
    ///
    /// Per-image failures all flow through the same frame shape with a
    /// real request id, whatever their origin: parse-layer rejects
    /// (non-finite pixels) arrive as `Err` images, bad payload sizes and
    /// queue backpressure fail at submission, and non-finite logits fail
    /// in the batcher.
    pub fn stream_batch(
        &self,
        model: &str,
        images: Vec<Result<Vec<f32>, String>>,
        emit: &mut dyn FnMut(&Response) -> bool,
    ) -> bool {
        // One path for every per-image frame, whatever layer produced the
        // body: record its outcome, count it, frame it, write it.
        fn emit_item(
            metrics: &Option<Arc<crate::coordinator::Metrics>>,
            ok_by_seq: &mut [Option<bool>],
            emit: &mut dyn FnMut(&Response) -> bool,
            seq: usize,
            id: u64,
            body: Response,
        ) -> bool {
            ok_by_seq[seq] = Some(!matches!(body, Response::Error(_)));
            let delivered = emit(&Response::StreamItem { seq, id, body: Box::new(body) });
            // count only frames actually written — a client hanging up
            // mid-stream must not inflate the stats op
            if delivered {
                if let Some(m) = metrics {
                    m.record_stream_frame();
                }
            }
            delivered
        }

        let metrics = self.router.metrics(model).ok();
        if let Some(m) = &metrics {
            m.record_stream();
        }
        let group = self.router.submit_group(model, images);
        let count = group.slots.len();
        let mut ok_by_seq: Vec<Option<bool>> = vec![None; count];
        // failure frames first for images that never reached the lane
        // (parse rejects, bad payloads, admission backpressure) — their
        // outcome is already known, the client shouldn't wait for it
        for (seq, slot) in group.slots.iter().enumerate() {
            if let Some(err) = &slot.error {
                let body = Response::Error(err.clone());
                if !emit_item(&metrics, &mut ok_by_seq, &mut *emit, seq, slot.id, body) {
                    return false;
                }
            }
        }
        // then one frame per admitted image, in completion order
        let seq_of_id: std::collections::HashMap<u64, usize> =
            group.slots.iter().enumerate().map(|(seq, s)| (s.id, seq)).collect();
        let mut pending = group.pending();
        while pending > 0 {
            match group.rx.recv() {
                Ok(resp) => {
                    pending -= 1;
                    // only this group's senders hold the channel, so the id
                    // always resolves; guard anyway — a session thread must
                    // never panic on traffic
                    let Some(&seq) = seq_of_id.get(&resp.id) else { continue };
                    let id = resp.id;
                    let body = self.render(resp);
                    if !emit_item(&metrics, &mut ok_by_seq, &mut *emit, seq, id, body) {
                        return false;
                    }
                }
                Err(_) => {
                    // the lane died mid-group: fail every still-pending
                    // image with its real id instead of hanging the client
                    for (seq, slot) in group.slots.iter().enumerate() {
                        if ok_by_seq[seq].is_none() {
                            let body = Response::Error(
                                "backend dropped the response channel".to_string(),
                            );
                            if !emit_item(&metrics, &mut ok_by_seq, &mut *emit, seq, slot.id, body)
                            {
                                return false;
                            }
                        }
                    }
                    break;
                }
            }
        }
        let results: Vec<StreamStatus> = group
            .slots
            .iter()
            .enumerate()
            .map(|(seq, slot)| StreamStatus {
                seq,
                id: slot.id,
                ok: ok_by_seq[seq].unwrap_or(false),
            })
            .collect();
        let completed = results.iter().filter(|s| s.ok).count();
        let end = Response::StreamEnd {
            count,
            completed,
            failed: count - completed,
            results,
        };
        emit(&end)
    }

    fn session(&self, stream: TcpStream) {
        let mut writer = match stream.try_clone() {
            Ok(w) => w,
            Err(_) => return,
        };
        let mut reader = BufReader::new(stream);
        let mut buf = Vec::new();
        loop {
            let resp = match read_line_bounded(&mut reader, &mut buf) {
                Ok(None) | Err(_) => break, // EOF or dead socket
                Ok(Some(Err(()))) => {
                    Response::Error(format!("request line exceeds {MAX_LINE_BYTES} bytes"))
                }
                Ok(Some(Ok(()))) => {
                    // invalid UTF-8 (e.g. binary garbage) must produce a
                    // protocol error, not kill the session
                    let parsed = {
                        let line = String::from_utf8_lossy(&buf);
                        if line.trim().is_empty() {
                            continue;
                        }
                        Request::parse(&line)
                    };
                    match parsed {
                        // the one multi-frame op: write each frame as it
                        // completes.  Backpressure is structural — while a
                        // slow client stalls a write here, completed
                        // responses buffer in the group's channel, which
                        // holds at most MAX_BATCH_IMAGES entries for this
                        // session; the lane's executors never block on it.
                        Ok(Request::ClassifyBatchStream { model, images }) => {
                            let alive = self.stream_batch(&model, images, &mut |frame| {
                                let mut out = frame.to_json_line();
                                out.push('\n');
                                writer.write_all(out.as_bytes()).is_ok()
                            });
                            if !alive {
                                break; // client gone mid-stream
                            }
                            buf.shrink_to(64 * 1024);
                            continue;
                        }
                        Ok(req) => self.handle(req),
                        Err(e) => Response::Error(e),
                    }
                }
            };
            let mut out = resp.to_json_line();
            out.push('\n');
            if writer.write_all(out.as_bytes()).is_err() {
                break;
            }
            // a maximal request mustn't pin tens of MB for an idle session
            buf.shrink_to(64 * 1024);
        }
    }

    /// Bind and serve until `stop` flips (or forever).  Returns the bound
    /// address once listening.
    pub fn serve(
        self: Arc<Self>,
        addr: &str,
        threads: usize,
        stop: Arc<AtomicBool>,
    ) -> std::io::Result<std::net::SocketAddr> {
        let listener = TcpListener::bind(addr)?;
        let local = listener.local_addr()?;
        listener.set_nonblocking(true)?;
        let pool = ThreadPool::new(threads, "server");
        std::thread::Builder::new().name("acceptor".into()).spawn(move || {
            loop {
                if stop.load(Ordering::Relaxed) {
                    break;
                }
                match listener.accept() {
                    Ok((stream, _)) => {
                        let _ = stream.set_nonblocking(false);
                        let me = Arc::clone(&self);
                        pool.execute(move || me.session(stream));
                    }
                    Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                        std::thread::sleep(std::time::Duration::from_millis(5));
                    }
                    Err(_) => break,
                }
            }
        })?;
        Ok(local)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bnn::network::tests_support::synth_bcnn_network;
    use crate::coordinator::{EngineBackend, InferBackend, Router};
    use crate::input::binarize::Scheme;

    fn test_server() -> Arc<Server> {
        let be: Arc<dyn InferBackend> =
            Arc::new(EngineBackend::bcnn(synth_bcnn_network(Scheme::Rgb, 5), 2));
        let router = Arc::new(Router::builder().variant("bcnn_rgb", be).build());
        Arc::new(Server::new(
            router,
            vec!["bus".into(), "normal".into(), "truck".into(), "van".into()],
        ))
    }

    #[test]
    fn handle_ping_and_variants() {
        let s = test_server();
        assert!(matches!(s.handle(Request::Ping), Response::Pong));
        match s.handle(Request::Variants) {
            Response::Variants(v) => assert_eq!(v, vec!["bcnn_rgb"]),
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn handle_classify_synth() {
        let s = test_server();
        match s.handle(Request::ClassifySynth { model: "".into(), index: 3 }) {
            Response::Classified { class, label, logits, batch, .. } => {
                assert!(class < 4);
                assert!(["bus", "normal", "truck", "van"].contains(&label.as_str()));
                assert_eq!(logits.len(), 4);
                assert_eq!(batch, 1);
            }
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn handle_bad_model() {
        let s = test_server();
        match s.handle(Request::ClassifySynth { model: "bogus".into(), index: 0 }) {
            Response::Error(e) => assert!(e.contains("bcnn_rgb")),
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn handle_classify_batch_mixed_validity() {
        let s = test_server();
        let good = vec![0.5f32; 96 * 96 * 3];
        let bad = vec![0.5f32; 10]; // wrong payload size -> per-image error
        match s.handle(Request::ClassifyBatch {
            model: "".into(),
            images: vec![good.clone(), bad, good],
        }) {
            Response::Batch(items) => {
                assert_eq!(items.len(), 3);
                assert!(matches!(items[0], Response::Classified { .. }));
                assert!(matches!(items[1], Response::Error(_)));
                assert!(matches!(items[2], Response::Classified { .. }));
            }
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn stream_batch_emits_per_image_frames_and_summary() {
        let s = test_server();
        let good = vec![0.5f32; 96 * 96 * 3];
        let frames = {
            let mut frames: Vec<Response> = Vec::new();
            let alive = s.stream_batch(
                "",
                vec![
                    Ok(good.clone()),
                    Err("non-finite pixel value".to_string()), // parse reject
                    Ok(vec![0.5f32; 10]),                      // bad payload
                ],
                &mut |frame| {
                    frames.push(frame.clone());
                    true
                },
            );
            assert!(alive);
            frames
        };
        assert_eq!(frames.len(), 4, "3 item frames + stream_end");
        // the two known-bad images fail first (no reason to wait), the
        // good image's frame follows on completion
        let mut ids = Vec::new();
        for frame in &frames[..3] {
            match frame {
                Response::StreamItem { id, body, seq } => {
                    ids.push(*id);
                    match (*seq, &**body) {
                        (0, Response::Classified { .. }) => {}
                        (1 | 2, Response::Error(_)) => {}
                        other => panic!("unexpected frame {other:?}"),
                    }
                }
                other => panic!("expected StreamItem, got {other:?}"),
            }
        }
        // real, distinct request ids on every frame — failures included
        ids.sort_unstable();
        ids.dedup();
        assert_eq!(ids.len(), 3);
        assert!(ids.iter().all(|&id| id != 0));
        match &frames[3] {
            Response::StreamEnd { count, completed, failed, results } => {
                assert_eq!((*count, *completed, *failed), (3, 1, 2));
                let seqs: Vec<usize> = results.iter().map(|r| r.seq).collect();
                assert_eq!(seqs, vec![0, 1, 2], "summary is in submission order");
                assert!(results[0].ok && !results[1].ok && !results[2].ok);
            }
            other => panic!("expected StreamEnd, got {other:?}"),
        }
        // the lane's stats op records the stream session and its frames
        let snap = s.router.metrics("").unwrap().snapshot();
        assert_eq!(snap.get("streams").unwrap().as_usize().unwrap(), 1);
        assert_eq!(snap.get("stream_frames").unwrap().as_usize().unwrap(), 3);
    }

    #[test]
    fn stream_batch_stops_when_client_goes_away() {
        let s = test_server();
        let good = vec![0.5f32; 96 * 96 * 3];
        let mut emitted = 0;
        let alive = s.stream_batch(
            "",
            vec![Ok(good.clone()), Ok(good)],
            &mut |_| {
                emitted += 1;
                false // client hung up on the first write
            },
        );
        assert!(!alive);
        assert_eq!(emitted, 1, "must stop emitting after a failed write");
    }

    #[test]
    fn handle_rejects_stream_op_on_single_response_path() {
        let s = test_server();
        match s.handle(Request::ClassifyBatchStream { model: "".into(), images: vec![] }) {
            Response::Error(e) => assert!(e.contains("streaming"), "{e}"),
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn read_line_bounded_handles_eof_and_split_lines() {
        let mut r = std::io::BufReader::new(&b"abc\ndef"[..]);
        let mut buf = Vec::new();
        assert_eq!(read_line_bounded(&mut r, &mut buf).unwrap(), Some(Ok(())));
        assert_eq!(buf, b"abc");
        assert_eq!(read_line_bounded(&mut r, &mut buf).unwrap(), Some(Ok(())));
        assert_eq!(buf, b"def"); // unterminated final line still delivered
        assert_eq!(read_line_bounded(&mut r, &mut buf).unwrap(), None);
    }

    #[test]
    fn tcp_end_to_end() {
        let s = test_server();
        let stop = Arc::new(AtomicBool::new(false));
        let addr = Arc::clone(&s).serve("127.0.0.1:0", 2, Arc::clone(&stop)).unwrap();
        let mut conn = TcpStream::connect(addr).unwrap();
        conn.write_all(b"{\"op\":\"classify_synth\",\"index\":1}\n{\"op\":\"stats\"}\n")
            .unwrap();
        let mut reader = BufReader::new(conn.try_clone().unwrap());
        let mut line = String::new();
        reader.read_line(&mut line).unwrap();
        assert!(line.contains("\"ok\": true") || line.contains("\"ok\":true"), "{line}");
        assert!(line.contains("label"));
        line.clear();
        reader.read_line(&mut line).unwrap();
        assert!(line.contains("stats"));
        stop.store(true, Ordering::Relaxed);
    }
}
