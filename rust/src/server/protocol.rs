//! Line-delimited JSON protocol.
//!
//! Requests (one JSON object per line):
//!
//! ```text
//!     {"op": "classify", "model": "bcnn", "pixels": [27648 floats],
//!      "trace": true}
//!     {"op": "classify_batch", "model": "bcnn@2",
//!      "images": [[27648 floats], [27648 floats], ...]}
//!     {"op": "classify_batch_stream", "model": "bcnn",
//!      "images": [[27648 floats], ...]}
//!     {"op": "classify_synth", "model": "bcnn", "index": 17}
//!     {"op": "stats"}
//!     {"op": "variants"}
//!     {"op": "ping"}
//!     {"op": "load_model", "name": "bcnn", "version": 2, "token": "s3cret"}
//!     {"op": "unload_model", "name": "bcnn", "version": 1, "token": "s3cret"}
//!     {"op": "set_default", "name": "bcnn", "version": 2, "token": "s3cret"}
//!     {"op": "list_models"}
//!     {"op": "metrics"}
//!     {"op": "trace_dump", "model": "bcnn@2"}
//! ```
//!
//! `model` on the classify ops is optional: empty/absent routes to the
//! registry's default entry, a bare name to that name's serving
//! version, `name@version` pins an exact entry.  Every successful
//! classification reports the `name@version` that served it.  The four
//! admin ops drive the hot-swap lifecycle (load → validate → publish →
//! retire) in [`crate::registry`].  When the server was started with
//! `--admin-token`, the three STATE-CHANGING admin ops (`load_model`,
//! `unload_model`, `set_default`) must carry a matching `"token"`
//! field; mismatches are refused and counted in `server.admin_denied`
//! (`list_models` stays read-only-open).
//!
//! Responses (one line each; a stream request produces several lines):
//!
//! ```text
//!     {"ok": true, "model": "bcnn@2", "class": 2, "label": "truck",
//!      "logits": [...], "queue_us": 12.0, "exec_us": 830.0, "batch": 1}
//!     {"ok": true, "results": [<classify responses, one per image>]}
//!     {"ok": true, "stream": true, "seq": 3, "id": 41, ...classify fields}
//!     {"ok": false, "stream": true, "seq": 1, "id": 39, "error": "..."}
//!     {"ok": true, "stream_end": true, "model": "bcnn@2", "count": 4,
//!      "completed": 3, "failed": 1,
//!      "results": [{"seq": 0, "id": 38, "ok": true}, ...]}
//!     {"ok": true, "stats": {...}} / {"ok": true, "variants": [...]}
//!     {"ok": false, "error": "..."}
//! ```
//!
//! `classify_batch` submits every image to the router back-to-back, so
//! the dynamic batcher can drain them into one batched backend call (up
//! to `BatchPolicy::max_batch`) — the wire-level entry to the batched
//! forward path.  At most [`MAX_BATCH_IMAGES`] images per request.
//!
//! `classify_batch_stream` submits the same way but answers with one
//! framed line per image **as it completes** (completion order, NOT
//! submission order — multi-executor lanes finish fast batches first),
//! then a terminal `stream_end` summary naming every per-image status in
//! submission order.  Unlike `classify_batch`, a malformed image (e.g. a
//! non-finite pixel) fails **per image** with its own frame and real
//! request id instead of rejecting the whole request — a stream client
//! consumes per-image status anyway.  See `docs/PROTOCOL.md` for the
//! full wire reference and worked sessions.

use crate::util::json::{Json, JsonObj};
use crate::util::trace::Trace;

/// Cap on images per `classify_batch` request (admission control at the
/// protocol layer; the batcher's `max_batch` governs execution grouping).
/// Sized so a maximal request (64 × 27648 floats, worst-case ~20 text
/// bytes per float ≈ 36 MB of JSON) fits under the transport's
/// `tcp::MAX_LINE_BYTES` (64 MiB) line cap.
pub const MAX_BATCH_IMAGES: usize = 64;

/// Parsed client request.
#[derive(Debug, Clone, PartialEq)]
pub enum Request {
    /// `trace: true` forces span capture for this request regardless of
    /// the server's sampling rate; the response then echoes the span
    /// timeline inline and the trace also lands in the trace store.
    Classify { model: String, pixels: Vec<f32>, trace: bool },
    ClassifyBatch { model: String, images: Vec<Vec<f32>> },
    /// Streaming variant: per-image parse failures ride along as `Err`
    /// entries (each will get a real request id and a failure frame)
    /// instead of rejecting the whole request like `ClassifyBatch`.
    ClassifyBatchStream { model: String, images: Vec<Result<Vec<f32>, String>> },
    ClassifySynth { model: String, index: usize },
    Stats,
    Variants,
    Ping,
    /// Admin: load + validate + publish `name@version` from the models
    /// directory (background loader; serving never blocks).  `token`
    /// must match the server's `--admin-token` when one is configured.
    LoadModel { name: String, version: u32, token: Option<String> },
    /// Admin: retire `name@version` (graceful drain).
    UnloadModel { name: String, version: u32, token: Option<String> },
    /// Admin: make `name` (at `version`, default its highest loaded
    /// one) the serving target for bare-`name` and default routing.
    SetDefault { name: String, version: Option<u32>, token: Option<String> },
    /// Admin: list resident entries with identity + per-model counters.
    ListModels,
    /// Flat Prometheus-style text exposition of every server, registry,
    /// and per-lane counter/gauge/histogram.
    Metrics,
    /// Drain the sampled-trace ring buffer (all traces, or only those
    /// served by `model` = an exact `name@version` lane key).
    TraceDump { model: Option<String> },
}

/// Server response payload.
#[derive(Debug, Clone)]
pub enum Response {
    Classified {
        /// The registry entry (`name@version`) that served this image —
        /// under a hot swap, clients see exactly which version answered.
        model: String,
        class: usize,
        label: String,
        logits: Vec<f32>,
        queue_us: f64,
        exec_us: f64,
        batch: usize,
        /// Span timeline, present only when the request forced tracing
        /// (`"trace": true`); rendered inline as a `"trace"` object.
        trace: Option<Box<Trace>>,
    },
    /// One entry per image of a `classify_batch` request (each entry is a
    /// `Classified` or a per-image `Error`).
    Batch(Vec<Response>),
    /// One per-image frame of a `classify_batch_stream` session: the
    /// wrapped `Classified`/`Error` body plus the image's submission
    /// index (`seq`) and request id, tagged `"stream": true` on the wire.
    StreamItem { seq: usize, id: u64, body: Box<Response> },
    /// Terminal frame of a stream session: per-image status in
    /// submission order, tagged `"stream_end": true` on the wire, and —
    /// like every per-image frame — naming the serving `name@version`
    /// (`model` is empty when the stream's model reference never
    /// resolved).
    StreamEnd {
        model: String,
        count: usize,
        completed: usize,
        failed: usize,
        results: Vec<StreamStatus>,
    },
    Stats(Json),
    Variants(Vec<String>),
    Pong,
    /// `list_models` body: per-entry rows plus registry lifecycle
    /// counters.  Each file-loaded row carries a `verify` object — the
    /// plan's static-verification envelope (step/weight/interval
    /// counts, slots and peak bytes per pool class) from
    /// [`crate::bnn::graph::VerifyReport`]; the counters include
    /// `verify_failures`, loads refused because verification failed.
    /// `kernel` names the runtime-dispatched XNOR microkernel serving
    /// this process (`scalar|tiled|swar|avx2|neon`).
    Models { models: Json, registry: Json, kernel: String },
    /// Acknowledgement of a state-changing admin op, naming the
    /// `name@version` it acted on.
    AdminAck { action: &'static str, model: String },
    /// `metrics` body: the full text exposition (one `name{labels} value`
    /// line per sample), shipped as a single JSON string field.
    Metrics(String),
    /// `trace_dump` body: the drained traces plus the store's cumulative
    /// ring-eviction count.
    Traces { traces: Json, dropped: u64 },
    Error(String),
}

/// One image's outcome in a `stream_end` summary.
#[derive(Debug, Clone, PartialEq)]
pub struct StreamStatus {
    /// Submission index within the request's `images` array.
    pub seq: usize,
    /// The router-assigned request id (matches the image's stream frame).
    pub id: u64,
    pub ok: bool,
}

/// Parse one pixel value, rejecting anything non-finite.
///
/// Two distinct overflow routes both used to smuggle `inf` into the
/// network (NaN logits → `argmax` silently answered class 0, "bus"):
/// `1e400` overflows f64 at JSON-parse time, and a finite-but-huge f64
/// like `1e200` overflows during the f32 cast — so the check runs AFTER
/// the cast.
fn finite_pixel(v: &Json) -> Result<f32, String> {
    let f = v.as_f64().map_err(|e| e.to_string())? as f32;
    if f.is_finite() {
        Ok(f)
    } else {
        Err("non-finite pixel value (inf/nan after f32 conversion)".to_string())
    }
}

/// Required `name` field of an admin op.
fn name_field(j: &Json) -> Result<String, String> {
    Ok(j.get("name").and_then(|n| n.as_str()).map_err(|e| e.to_string())?.to_string())
}

/// Optional `token` field of a state-changing admin op (checked against
/// the server's `--admin-token` when one is configured).
fn token_field(j: &Json) -> Result<Option<String>, String> {
    match j.get_opt("token").map_err(|e| e.to_string())? {
        Some(t) => Ok(Some(t.as_str().map_err(|e| e.to_string())?.to_string())),
        None => Ok(None),
    }
}

/// Required `version` field of an admin op (u32, >= 1).
fn version_field(j: &Json) -> Result<u32, String> {
    let v = j.get("version").and_then(|v| v.as_usize()).map_err(|e| e.to_string())?;
    match u32::try_from(v) {
        Ok(v) if v >= 1 => Ok(v),
        _ => Err(format!("version {v} must be in 1..=4294967295")),
    }
}

impl Request {
    pub fn parse(line: &str) -> Result<Request, String> {
        let j = Json::parse(line).map_err(|e| e.to_string())?;
        let op = j.get("op").and_then(|o| o.as_str()).map_err(|e| e.to_string())?;
        let model = j
            .get_opt("model")
            .ok()
            .flatten()
            .and_then(|m| m.as_str().ok())
            .unwrap_or("")
            .to_string();
        match op {
            "classify" => {
                let pixels = j
                    .get("pixels")
                    .and_then(|p| p.as_arr())
                    .map_err(|e| e.to_string())?
                    .iter()
                    .map(finite_pixel)
                    .collect::<Result<Vec<_>, String>>()?;
                let trace = match j.get_opt("trace").map_err(|e| e.to_string())? {
                    Some(t) => t.as_bool().map_err(|e| e.to_string())?,
                    None => false,
                };
                Ok(Request::Classify { model, pixels, trace })
            }
            "classify_batch" => {
                let arr = j.get("images").and_then(|p| p.as_arr()).map_err(|e| e.to_string())?;
                if arr.len() > MAX_BATCH_IMAGES {
                    return Err(format!(
                        "classify_batch: {} images exceeds the limit of {MAX_BATCH_IMAGES}",
                        arr.len()
                    ));
                }
                let images = arr
                    .iter()
                    .map(|img| {
                        img.as_arr()
                            .map_err(|e| e.to_string())?
                            .iter()
                            .map(finite_pixel)
                            .collect::<Result<Vec<f32>, String>>()
                    })
                    .collect::<Result<Vec<_>, String>>()?;
                Ok(Request::ClassifyBatch { model, images })
            }
            "classify_batch_stream" => {
                let arr = j.get("images").and_then(|p| p.as_arr()).map_err(|e| e.to_string())?;
                if arr.len() > MAX_BATCH_IMAGES {
                    return Err(format!(
                        "classify_batch_stream: {} images exceeds the limit of {MAX_BATCH_IMAGES}",
                        arr.len()
                    ));
                }
                // per-image errors are DEFERRED, not fatal: each Err entry
                // becomes a per-image failure frame with a real request id
                let images = arr
                    .iter()
                    .map(|img| {
                        img.as_arr()
                            .map_err(|e| e.to_string())?
                            .iter()
                            .map(finite_pixel)
                            .collect::<Result<Vec<f32>, String>>()
                    })
                    .collect();
                Ok(Request::ClassifyBatchStream { model, images })
            }
            "classify_synth" => {
                let index =
                    j.get("index").and_then(|i| i.as_usize()).map_err(|e| e.to_string())?;
                Ok(Request::ClassifySynth { model, index })
            }
            "stats" => Ok(Request::Stats),
            "variants" => Ok(Request::Variants),
            "ping" => Ok(Request::Ping),
            "load_model" => Ok(Request::LoadModel {
                name: name_field(&j)?,
                version: version_field(&j)?,
                token: token_field(&j)?,
            }),
            "unload_model" => Ok(Request::UnloadModel {
                name: name_field(&j)?,
                version: version_field(&j)?,
                token: token_field(&j)?,
            }),
            "set_default" => {
                let version = match j.get_opt("version").map_err(|e| e.to_string())? {
                    None => None,
                    Some(_) => Some(version_field(&j)?),
                };
                Ok(Request::SetDefault { name: name_field(&j)?, version, token: token_field(&j)? })
            }
            "list_models" => Ok(Request::ListModels),
            "metrics" => Ok(Request::Metrics),
            "trace_dump" => {
                Ok(Request::TraceDump { model: (!model.is_empty()).then(|| model.clone()) })
            }
            other => Err(format!("unknown op {other:?}")),
        }
    }
}

impl Response {
    fn to_json_obj(&self) -> JsonObj {
        let mut obj = JsonObj::new();
        match self {
            Response::Classified {
                model,
                class,
                label,
                logits,
                queue_us,
                exec_us,
                batch,
                trace,
            } => {
                obj.insert("ok", Json::Bool(true));
                obj.insert("model", Json::from(model.as_str()));
                obj.insert("class", Json::from(*class));
                obj.insert("label", Json::from(label.as_str()));
                obj.insert(
                    "logits",
                    Json::Arr(logits.iter().map(|&v| Json::Num(v as f64)).collect()),
                );
                obj.insert("queue_us", Json::from(*queue_us));
                obj.insert("exec_us", Json::from(*exec_us));
                obj.insert("batch", Json::from(*batch));
                if let Some(t) = trace {
                    obj.insert("trace", t.to_json());
                }
            }
            Response::Batch(items) => {
                obj.insert("ok", Json::Bool(true));
                obj.insert(
                    "results",
                    Json::Arr(items.iter().map(|r| Json::Obj(r.to_json_obj())).collect()),
                );
            }
            Response::StreamItem { seq, id, body } => {
                // the body's own fields (incl. its "ok") plus stream tags
                obj = body.to_json_obj();
                obj.insert("stream", Json::Bool(true));
                obj.insert("seq", Json::from(*seq));
                obj.insert("id", Json::from(*id as usize));
            }
            Response::StreamEnd { model, count, completed, failed, results } => {
                obj.insert("ok", Json::Bool(true));
                obj.insert("stream_end", Json::Bool(true));
                obj.insert("model", Json::from(model.as_str()));
                obj.insert("count", Json::from(*count));
                obj.insert("completed", Json::from(*completed));
                obj.insert("failed", Json::from(*failed));
                obj.insert(
                    "results",
                    Json::Arr(
                        results
                            .iter()
                            .map(|s| {
                                let mut o = JsonObj::new();
                                o.insert("seq", Json::from(s.seq));
                                o.insert("id", Json::from(s.id as usize));
                                o.insert("ok", Json::Bool(s.ok));
                                Json::Obj(o)
                            })
                            .collect(),
                    ),
                );
            }
            Response::Stats(s) => {
                obj.insert("ok", Json::Bool(true));
                obj.insert("stats", s.clone());
            }
            Response::Variants(v) => {
                obj.insert("ok", Json::Bool(true));
                obj.insert(
                    "variants",
                    Json::Arr(v.iter().map(|s| Json::from(s.as_str())).collect()),
                );
            }
            Response::Pong => {
                obj.insert("ok", Json::Bool(true));
                obj.insert("pong", Json::Bool(true));
            }
            Response::Models { models, registry, kernel } => {
                obj.insert("ok", Json::Bool(true));
                obj.insert("models", models.clone());
                obj.insert("registry", registry.clone());
                obj.insert("kernel", Json::from(kernel.as_str()));
            }
            Response::AdminAck { action, model } => {
                obj.insert("ok", Json::Bool(true));
                obj.insert("action", Json::from(*action));
                obj.insert("model", Json::from(model.as_str()));
            }
            Response::Metrics(text) => {
                obj.insert("ok", Json::Bool(true));
                obj.insert("metrics", Json::from(text.as_str()));
            }
            Response::Traces { traces, dropped } => {
                obj.insert("ok", Json::Bool(true));
                obj.insert("traces", traces.clone());
                obj.insert("dropped", Json::from(*dropped as usize));
            }
            Response::Error(msg) => {
                obj.insert("ok", Json::Bool(false));
                obj.insert("error", Json::from(msg.as_str()));
            }
        }
        obj
    }

    pub fn to_json_line(&self) -> String {
        Json::Obj(self.to_json_obj()).to_string()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_classify_synth() {
        let r = Request::parse(r#"{"op":"classify_synth","model":"float","index":5}"#).unwrap();
        assert_eq!(r, Request::ClassifySynth { model: "float".into(), index: 5 });
    }

    #[test]
    fn parse_classify_pixels() {
        let r = Request::parse(r#"{"op":"classify","pixels":[0.5, 1.0]}"#).unwrap();
        match r {
            Request::Classify { model, pixels, trace } => {
                assert_eq!(model, "");
                assert_eq!(pixels, vec![0.5, 1.0]);
                assert!(!trace, "tracing is opt-in per request");
            }
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn parse_classify_trace_flag() {
        let r = Request::parse(r#"{"op":"classify","pixels":[0.5],"trace":true}"#).unwrap();
        assert!(matches!(r, Request::Classify { trace: true, .. }));
        let r = Request::parse(r#"{"op":"classify","pixels":[0.5],"trace":false}"#).unwrap();
        assert!(matches!(r, Request::Classify { trace: false, .. }));
        // a non-boolean trace flag is malformed, not silently ignored
        assert!(Request::parse(r#"{"op":"classify","pixels":[0.5],"trace":1}"#).is_err());
    }

    #[test]
    fn parse_metrics_and_trace_dump_ops() {
        assert_eq!(Request::parse(r#"{"op":"metrics"}"#).unwrap(), Request::Metrics);
        assert_eq!(
            Request::parse(r#"{"op":"trace_dump"}"#).unwrap(),
            Request::TraceDump { model: None }
        );
        assert_eq!(
            Request::parse(r#"{"op":"trace_dump","model":"bcnn@2"}"#).unwrap(),
            Request::TraceDump { model: Some("bcnn@2".into()) }
        );
    }

    #[test]
    fn parse_control_ops() {
        assert_eq!(Request::parse(r#"{"op":"stats"}"#).unwrap(), Request::Stats);
        assert_eq!(Request::parse(r#"{"op":"variants"}"#).unwrap(), Request::Variants);
        assert_eq!(Request::parse(r#"{"op":"ping"}"#).unwrap(), Request::Ping);
    }

    #[test]
    fn parse_admin_ops() {
        assert_eq!(
            Request::parse(r#"{"op":"load_model","name":"bcnn","version":2}"#).unwrap(),
            Request::LoadModel { name: "bcnn".into(), version: 2, token: None }
        );
        assert_eq!(
            Request::parse(r#"{"op":"unload_model","name":"bcnn","version":1}"#).unwrap(),
            Request::UnloadModel { name: "bcnn".into(), version: 1, token: None }
        );
        assert_eq!(
            Request::parse(r#"{"op":"set_default","name":"bcnn","version":2}"#).unwrap(),
            Request::SetDefault { name: "bcnn".into(), version: Some(2), token: None }
        );
        assert_eq!(
            Request::parse(r#"{"op":"set_default","name":"bcnn"}"#).unwrap(),
            Request::SetDefault { name: "bcnn".into(), version: None, token: None }
        );
        assert_eq!(Request::parse(r#"{"op":"list_models"}"#).unwrap(), Request::ListModels);
    }

    #[test]
    fn parse_admin_token_field() {
        assert_eq!(
            Request::parse(r#"{"op":"load_model","name":"b","version":2,"token":"s3cret"}"#)
                .unwrap(),
            Request::LoadModel { name: "b".into(), version: 2, token: Some("s3cret".into()) }
        );
        assert_eq!(
            Request::parse(r#"{"op":"set_default","name":"b","token":"t"}"#).unwrap(),
            Request::SetDefault { name: "b".into(), version: None, token: Some("t".into()) }
        );
        // a non-string token is malformed, not silently ignored
        assert!(Request::parse(r#"{"op":"load_model","name":"b","version":2,"token":7}"#)
            .is_err());
    }

    #[test]
    fn admin_ops_reject_malformed_fields() {
        // missing name / version
        assert!(Request::parse(r#"{"op":"load_model","version":1}"#).is_err());
        assert!(Request::parse(r#"{"op":"load_model","name":"bcnn"}"#).is_err());
        assert!(Request::parse(r#"{"op":"unload_model","name":"bcnn"}"#).is_err());
        // version bounds: 0 and >u32::MAX are refused at parse
        assert!(Request::parse(r#"{"op":"load_model","name":"b","version":0}"#).is_err());
        assert!(
            Request::parse(r#"{"op":"set_default","name":"b","version":5000000000}"#).is_err()
        );
    }

    #[test]
    fn admin_response_shapes() {
        let ack = Response::AdminAck { action: "set_default", model: "bcnn@2".into() };
        let j = Json::parse(&ack.to_json_line()).unwrap();
        assert!(j.get("ok").unwrap().as_bool().unwrap());
        assert_eq!(j.get("action").unwrap().as_str().unwrap(), "set_default");
        assert_eq!(j.get("model").unwrap().as_str().unwrap(), "bcnn@2");

        let models = Response::Models {
            models: Json::Arr(vec![]),
            registry: Json::Obj(JsonObj::new()),
            kernel: "tiled".to_string(),
        };
        let j = Json::parse(&models.to_json_line()).unwrap();
        assert!(j.get("ok").unwrap().as_bool().unwrap());
        assert_eq!(j.get("models").unwrap().as_arr().unwrap().len(), 0);
        assert!(j.get("registry").is_ok());
        assert_eq!(j.get("kernel").unwrap().as_str().unwrap(), "tiled");
    }

    #[test]
    fn parse_rejects_garbage() {
        assert!(Request::parse("not json").is_err());
        assert!(Request::parse(r#"{"op":"fly"}"#).is_err());
        assert!(Request::parse(r#"{"nop":"classify"}"#).is_err());
    }

    #[test]
    fn parse_rejects_non_finite_pixels() {
        // 1e400 overflows f64 to +inf at JSON-parse time
        assert!(Request::parse(r#"{"op":"classify","pixels":[1e400]}"#).is_err());
        // 1e200 is a finite f64 but overflows the f32 cast
        assert!(Request::parse(r#"{"op":"classify","pixels":[0.5,1e200]}"#).is_err());
        assert!(Request::parse(r#"{"op":"classify","pixels":[-1e400]}"#).is_err());
        // batch variant enforces the same check per pixel
        assert!(Request::parse(r#"{"op":"classify_batch","images":[[0.5,1e400]]}"#).is_err());
        // ordinary pixels still parse
        assert!(Request::parse(r#"{"op":"classify","pixels":[0.0,0.5,1.0]}"#).is_ok());
    }

    #[test]
    fn parse_classify_batch() {
        let r = Request::parse(r#"{"op":"classify_batch","model":"rgb","images":[[1.0,2.0],[3.0,4.0]]}"#)
            .unwrap();
        match r {
            Request::ClassifyBatch { model, images } => {
                assert_eq!(model, "rgb");
                assert_eq!(images, vec![vec![1.0, 2.0], vec![3.0, 4.0]]);
            }
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn classify_batch_rejects_malformed() {
        // missing images
        assert!(Request::parse(r#"{"op":"classify_batch"}"#).is_err());
        // non-array image entry
        assert!(Request::parse(r#"{"op":"classify_batch","images":[1.0]}"#).is_err());
        // non-numeric pixel
        assert!(Request::parse(r#"{"op":"classify_batch","images":[["x"]]}"#).is_err());
    }

    #[test]
    fn parse_classify_batch_stream_defers_per_image_errors() {
        // one good image, one non-finite pixel, one non-array entry: the
        // request parses, and the bad entries ride along as Err slots
        let r = Request::parse(
            r#"{"op":"classify_batch_stream","model":"rgb","images":[[0.5,1.0],[0.5,1e400],7]}"#,
        )
        .unwrap();
        match r {
            Request::ClassifyBatchStream { model, images } => {
                assert_eq!(model, "rgb");
                assert_eq!(images.len(), 3);
                assert_eq!(images[0], Ok(vec![0.5, 1.0]));
                assert!(images[1].as_ref().unwrap_err().contains("non-finite"));
                assert!(images[2].is_err());
            }
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn classify_batch_stream_still_caps_group_size() {
        let imgs = vec!["[0.5]"; MAX_BATCH_IMAGES + 1].join(",");
        let req = format!("{{\"op\":\"classify_batch_stream\",\"images\":[{imgs}]}}");
        let err = Request::parse(&req).unwrap_err();
        assert!(err.contains("exceeds the limit"), "{err}");
    }

    #[test]
    fn stream_item_frame_carries_body_and_tags() {
        let ok = Response::StreamItem {
            seq: 3,
            id: 41,
            body: Box::new(Response::Classified {
                model: "bcnn@2".into(),
                class: 2,
                label: "truck".into(),
                logits: vec![0.0, 0.0, 1.0, 0.0],
                queue_us: 1.0,
                exec_us: 2.0,
                batch: 4,
                trace: None,
            }),
        };
        let j = Json::parse(&ok.to_json_line()).unwrap();
        assert!(j.get("ok").unwrap().as_bool().unwrap());
        assert!(j.get("stream").unwrap().as_bool().unwrap());
        assert_eq!(j.get("seq").unwrap().as_usize().unwrap(), 3);
        assert_eq!(j.get("id").unwrap().as_usize().unwrap(), 41);
        assert_eq!(j.get("label").unwrap().as_str().unwrap(), "truck");

        let err = Response::StreamItem {
            seq: 1,
            id: 39,
            body: Box::new(Response::Error("non-finite logits".into())),
        };
        let j = Json::parse(&err.to_json_line()).unwrap();
        assert!(!j.get("ok").unwrap().as_bool().unwrap());
        assert!(j.get("stream").unwrap().as_bool().unwrap());
        assert_eq!(j.get("id").unwrap().as_usize().unwrap(), 39);
        assert!(j.get("error").unwrap().as_str().unwrap().contains("non-finite"));
    }

    #[test]
    fn stream_end_frame_summarizes_in_submission_order() {
        let end = Response::StreamEnd {
            model: "bcnn@2".into(),
            count: 2,
            completed: 1,
            failed: 1,
            results: vec![
                StreamStatus { seq: 0, id: 38, ok: true },
                StreamStatus { seq: 1, id: 39, ok: false },
            ],
        };
        let j = Json::parse(&end.to_json_line()).unwrap();
        assert!(j.get("ok").unwrap().as_bool().unwrap());
        assert!(j.get("stream_end").unwrap().as_bool().unwrap());
        // regression (PR 4 added `model` to Classified only): the
        // terminal summary names the serving entry like per-image frames
        assert_eq!(j.get("model").unwrap().as_str().unwrap(), "bcnn@2");
        assert_eq!(j.get("count").unwrap().as_usize().unwrap(), 2);
        assert_eq!(j.get("completed").unwrap().as_usize().unwrap(), 1);
        assert_eq!(j.get("failed").unwrap().as_usize().unwrap(), 1);
        let results = j.get("results").unwrap().as_arr().unwrap();
        assert_eq!(results[0].get("seq").unwrap().as_usize().unwrap(), 0);
        assert!(results[0].get("ok").unwrap().as_bool().unwrap());
        assert_eq!(results[1].get("id").unwrap().as_usize().unwrap(), 39);
        assert!(!results[1].get("ok").unwrap().as_bool().unwrap());
    }

    #[test]
    fn batch_response_renders_per_image_results() {
        let r = Response::Batch(vec![
            Response::Classified {
                model: "bcnn@1".into(),
                class: 1,
                label: "normal".into(),
                logits: vec![0.0, 1.0, 0.0, 0.0],
                queue_us: 1.0,
                exec_us: 2.0,
                batch: 2,
                trace: None,
            },
            Response::Error("bad image".into()),
        ]);
        let j = Json::parse(&r.to_json_line()).unwrap();
        assert!(j.get("ok").unwrap().as_bool().unwrap());
        let results = j.get("results").unwrap().as_arr().unwrap();
        assert_eq!(results.len(), 2);
        assert!(results[0].get("ok").unwrap().as_bool().unwrap());
        assert_eq!(results[0].get("label").unwrap().as_str().unwrap(), "normal");
        assert!(!results[1].get("ok").unwrap().as_bool().unwrap());
    }

    #[test]
    fn response_roundtrips_through_json() {
        let r = Response::Classified {
            model: "bcnn@1".into(),
            class: 2,
            label: "truck".into(),
            logits: vec![0.1, -0.5, 3.0, 0.0],
            queue_us: 11.5,
            exec_us: 820.0,
            batch: 1,
            trace: None,
        };
        let line = r.to_json_line();
        let j = Json::parse(&line).unwrap();
        assert!(j.get("ok").unwrap().as_bool().unwrap());
        assert_eq!(j.get("model").unwrap().as_str().unwrap(), "bcnn@1");
        assert_eq!(j.get("class").unwrap().as_usize().unwrap(), 2);
        assert_eq!(j.get("label").unwrap().as_str().unwrap(), "truck");
        assert_eq!(j.get("logits").unwrap().as_arr().unwrap().len(), 4);
        // no trace → no "trace" key on the wire
        assert!(j.get_opt("trace").unwrap().is_none());
    }

    #[test]
    fn classified_renders_an_inline_trace_when_forced() {
        let mut t = Trace::begin();
        t.id = 7;
        t.model = "bcnn@1".into();
        t.push("parsed", 1_000);
        t.push("logits", 5_000);
        let r = Response::Classified {
            model: "bcnn@1".into(),
            class: 0,
            label: "bus".into(),
            logits: vec![1.0],
            queue_us: 1.0,
            exec_us: 2.0,
            batch: 1,
            trace: Some(Box::new(t)),
        };
        let j = Json::parse(&r.to_json_line()).unwrap();
        let trace = j.get("trace").unwrap();
        assert_eq!(trace.get("id").unwrap().as_usize().unwrap(), 7);
        assert_eq!(trace.get("model").unwrap().as_str().unwrap(), "bcnn@1");
        let spans = trace.get("spans").unwrap().as_arr().unwrap();
        assert_eq!(spans.len(), 2);
        assert_eq!(spans[0].get("label").unwrap().as_str().unwrap(), "parsed");
        assert_eq!(spans[1].get("us").unwrap().as_f64().unwrap(), 5.0);
    }

    #[test]
    fn metrics_and_traces_response_shapes() {
        let m = Response::Metrics("bcnn_uptime_seconds 1\nbcnn_live_sessions 0\n".into());
        let j = Json::parse(&m.to_json_line()).unwrap();
        assert!(j.get("ok").unwrap().as_bool().unwrap());
        assert!(j.get("metrics").unwrap().as_str().unwrap().contains("bcnn_uptime_seconds"));

        let t = Response::Traces { traces: Json::Arr(vec![]), dropped: 3 };
        let j = Json::parse(&t.to_json_line()).unwrap();
        assert!(j.get("ok").unwrap().as_bool().unwrap());
        assert_eq!(j.get("traces").unwrap().as_arr().unwrap().len(), 0);
        assert_eq!(j.get("dropped").unwrap().as_usize().unwrap(), 3);
    }

    #[test]
    fn error_response_shape() {
        let line = Response::Error("bad".into()).to_json_line();
        let j = Json::parse(&line).unwrap();
        assert!(!j.get("ok").unwrap().as_bool().unwrap());
        assert_eq!(j.get("error").unwrap().as_str().unwrap(), "bad");
    }
}
