//! Line-delimited JSON protocol.
//!
//! Requests (one JSON object per line):
//!
//! ```text
//!     {"op": "classify", "model": "bcnn_rgb", "pixels": [27648 floats]}
//!     {"op": "classify_batch", "model": "bcnn_rgb",
//!      "images": [[27648 floats], [27648 floats], ...]}
//!     {"op": "classify_synth", "model": "bcnn_rgb", "index": 17}
//!     {"op": "stats"}
//!     {"op": "variants"}
//!     {"op": "ping"}
//! ```
//!
//! Responses:
//!
//! ```text
//!     {"ok": true, "class": 2, "label": "truck", "logits": [...],
//!      "queue_us": 12.0, "exec_us": 830.0, "batch": 1}
//!     {"ok": true, "results": [<classify responses, one per image>]}
//!     {"ok": true, "stats": {...}} / {"ok": true, "variants": [...]}
//!     {"ok": false, "error": "..."}
//! ```
//!
//! `classify_batch` submits every image to the router back-to-back, so
//! the dynamic batcher can drain them into one batched backend call (up
//! to `BatchPolicy::max_batch`) — the wire-level entry to the batched
//! forward path.  At most [`MAX_BATCH_IMAGES`] images per request.

use crate::util::json::{Json, JsonObj};

/// Cap on images per `classify_batch` request (admission control at the
/// protocol layer; the batcher's `max_batch` governs execution grouping).
/// Sized so a maximal request (64 × 27648 floats, worst-case ~20 text
/// bytes per float ≈ 36 MB of JSON) fits under the transport's
/// `tcp::MAX_LINE_BYTES` (64 MiB) line cap.
pub const MAX_BATCH_IMAGES: usize = 64;

/// Parsed client request.
#[derive(Debug, Clone, PartialEq)]
pub enum Request {
    Classify { model: String, pixels: Vec<f32> },
    ClassifyBatch { model: String, images: Vec<Vec<f32>> },
    ClassifySynth { model: String, index: usize },
    Stats,
    Variants,
    Ping,
}

/// Server response payload.
#[derive(Debug, Clone)]
pub enum Response {
    Classified {
        class: usize,
        label: String,
        logits: Vec<f32>,
        queue_us: f64,
        exec_us: f64,
        batch: usize,
    },
    /// One entry per image of a `classify_batch` request (each entry is a
    /// `Classified` or a per-image `Error`).
    Batch(Vec<Response>),
    Stats(Json),
    Variants(Vec<String>),
    Pong,
    Error(String),
}

/// Parse one pixel value, rejecting anything non-finite.
///
/// Two distinct overflow routes both used to smuggle `inf` into the
/// network (NaN logits → `argmax` silently answered class 0, "bus"):
/// `1e400` overflows f64 at JSON-parse time, and a finite-but-huge f64
/// like `1e200` overflows during the f32 cast — so the check runs AFTER
/// the cast.
fn finite_pixel(v: &Json) -> Result<f32, String> {
    let f = v.as_f64().map_err(|e| e.to_string())? as f32;
    if f.is_finite() {
        Ok(f)
    } else {
        Err("non-finite pixel value (inf/nan after f32 conversion)".to_string())
    }
}

impl Request {
    pub fn parse(line: &str) -> Result<Request, String> {
        let j = Json::parse(line).map_err(|e| e.to_string())?;
        let op = j.get("op").and_then(|o| o.as_str()).map_err(|e| e.to_string())?;
        let model = j
            .get_opt("model")
            .ok()
            .flatten()
            .and_then(|m| m.as_str().ok())
            .unwrap_or("")
            .to_string();
        match op {
            "classify" => {
                let pixels = j
                    .get("pixels")
                    .and_then(|p| p.as_arr())
                    .map_err(|e| e.to_string())?
                    .iter()
                    .map(finite_pixel)
                    .collect::<Result<Vec<_>, String>>()?;
                Ok(Request::Classify { model, pixels })
            }
            "classify_batch" => {
                let arr = j.get("images").and_then(|p| p.as_arr()).map_err(|e| e.to_string())?;
                if arr.len() > MAX_BATCH_IMAGES {
                    return Err(format!(
                        "classify_batch: {} images exceeds the limit of {MAX_BATCH_IMAGES}",
                        arr.len()
                    ));
                }
                let images = arr
                    .iter()
                    .map(|img| {
                        img.as_arr()
                            .map_err(|e| e.to_string())?
                            .iter()
                            .map(finite_pixel)
                            .collect::<Result<Vec<f32>, String>>()
                    })
                    .collect::<Result<Vec<_>, String>>()?;
                Ok(Request::ClassifyBatch { model, images })
            }
            "classify_synth" => {
                let index =
                    j.get("index").and_then(|i| i.as_usize()).map_err(|e| e.to_string())?;
                Ok(Request::ClassifySynth { model, index })
            }
            "stats" => Ok(Request::Stats),
            "variants" => Ok(Request::Variants),
            "ping" => Ok(Request::Ping),
            other => Err(format!("unknown op {other:?}")),
        }
    }
}

impl Response {
    fn to_json_obj(&self) -> JsonObj {
        let mut obj = JsonObj::new();
        match self {
            Response::Classified { class, label, logits, queue_us, exec_us, batch } => {
                obj.insert("ok", Json::Bool(true));
                obj.insert("class", Json::from(*class));
                obj.insert("label", Json::from(label.as_str()));
                obj.insert(
                    "logits",
                    Json::Arr(logits.iter().map(|&v| Json::Num(v as f64)).collect()),
                );
                obj.insert("queue_us", Json::from(*queue_us));
                obj.insert("exec_us", Json::from(*exec_us));
                obj.insert("batch", Json::from(*batch));
            }
            Response::Batch(items) => {
                obj.insert("ok", Json::Bool(true));
                obj.insert(
                    "results",
                    Json::Arr(items.iter().map(|r| Json::Obj(r.to_json_obj())).collect()),
                );
            }
            Response::Stats(s) => {
                obj.insert("ok", Json::Bool(true));
                obj.insert("stats", s.clone());
            }
            Response::Variants(v) => {
                obj.insert("ok", Json::Bool(true));
                obj.insert(
                    "variants",
                    Json::Arr(v.iter().map(|s| Json::from(s.as_str())).collect()),
                );
            }
            Response::Pong => {
                obj.insert("ok", Json::Bool(true));
                obj.insert("pong", Json::Bool(true));
            }
            Response::Error(msg) => {
                obj.insert("ok", Json::Bool(false));
                obj.insert("error", Json::from(msg.as_str()));
            }
        }
        obj
    }

    pub fn to_json_line(&self) -> String {
        Json::Obj(self.to_json_obj()).to_string()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_classify_synth() {
        let r = Request::parse(r#"{"op":"classify_synth","model":"float","index":5}"#).unwrap();
        assert_eq!(r, Request::ClassifySynth { model: "float".into(), index: 5 });
    }

    #[test]
    fn parse_classify_pixels() {
        let r = Request::parse(r#"{"op":"classify","pixels":[0.5, 1.0]}"#).unwrap();
        match r {
            Request::Classify { model, pixels } => {
                assert_eq!(model, "");
                assert_eq!(pixels, vec![0.5, 1.0]);
            }
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn parse_control_ops() {
        assert_eq!(Request::parse(r#"{"op":"stats"}"#).unwrap(), Request::Stats);
        assert_eq!(Request::parse(r#"{"op":"variants"}"#).unwrap(), Request::Variants);
        assert_eq!(Request::parse(r#"{"op":"ping"}"#).unwrap(), Request::Ping);
    }

    #[test]
    fn parse_rejects_garbage() {
        assert!(Request::parse("not json").is_err());
        assert!(Request::parse(r#"{"op":"fly"}"#).is_err());
        assert!(Request::parse(r#"{"nop":"classify"}"#).is_err());
    }

    #[test]
    fn parse_rejects_non_finite_pixels() {
        // 1e400 overflows f64 to +inf at JSON-parse time
        assert!(Request::parse(r#"{"op":"classify","pixels":[1e400]}"#).is_err());
        // 1e200 is a finite f64 but overflows the f32 cast
        assert!(Request::parse(r#"{"op":"classify","pixels":[0.5,1e200]}"#).is_err());
        assert!(Request::parse(r#"{"op":"classify","pixels":[-1e400]}"#).is_err());
        // batch variant enforces the same check per pixel
        assert!(Request::parse(r#"{"op":"classify_batch","images":[[0.5,1e400]]}"#).is_err());
        // ordinary pixels still parse
        assert!(Request::parse(r#"{"op":"classify","pixels":[0.0,0.5,1.0]}"#).is_ok());
    }

    #[test]
    fn parse_classify_batch() {
        let r = Request::parse(r#"{"op":"classify_batch","model":"rgb","images":[[1.0,2.0],[3.0,4.0]]}"#)
            .unwrap();
        match r {
            Request::ClassifyBatch { model, images } => {
                assert_eq!(model, "rgb");
                assert_eq!(images, vec![vec![1.0, 2.0], vec![3.0, 4.0]]);
            }
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn classify_batch_rejects_malformed() {
        // missing images
        assert!(Request::parse(r#"{"op":"classify_batch"}"#).is_err());
        // non-array image entry
        assert!(Request::parse(r#"{"op":"classify_batch","images":[1.0]}"#).is_err());
        // non-numeric pixel
        assert!(Request::parse(r#"{"op":"classify_batch","images":[["x"]]}"#).is_err());
    }

    #[test]
    fn batch_response_renders_per_image_results() {
        let r = Response::Batch(vec![
            Response::Classified {
                class: 1,
                label: "normal".into(),
                logits: vec![0.0, 1.0, 0.0, 0.0],
                queue_us: 1.0,
                exec_us: 2.0,
                batch: 2,
            },
            Response::Error("bad image".into()),
        ]);
        let j = Json::parse(&r.to_json_line()).unwrap();
        assert!(j.get("ok").unwrap().as_bool().unwrap());
        let results = j.get("results").unwrap().as_arr().unwrap();
        assert_eq!(results.len(), 2);
        assert!(results[0].get("ok").unwrap().as_bool().unwrap());
        assert_eq!(results[0].get("label").unwrap().as_str().unwrap(), "normal");
        assert!(!results[1].get("ok").unwrap().as_bool().unwrap());
    }

    #[test]
    fn response_roundtrips_through_json() {
        let r = Response::Classified {
            class: 2,
            label: "truck".into(),
            logits: vec![0.1, -0.5, 3.0, 0.0],
            queue_us: 11.5,
            exec_us: 820.0,
            batch: 1,
        };
        let line = r.to_json_line();
        let j = Json::parse(&line).unwrap();
        assert!(j.get("ok").unwrap().as_bool().unwrap());
        assert_eq!(j.get("class").unwrap().as_usize().unwrap(), 2);
        assert_eq!(j.get("label").unwrap().as_str().unwrap(), "truck");
        assert_eq!(j.get("logits").unwrap().as_arr().unwrap().len(), 4);
    }

    #[test]
    fn error_response_shape() {
        let line = Response::Error("bad".into()).to_json_line();
        let j = Json::parse(&line).unwrap();
        assert!(!j.get("ok").unwrap().as_bool().unwrap());
        assert_eq!(j.get("error").unwrap().as_str().unwrap(), "bad");
    }
}
