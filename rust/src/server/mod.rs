//! TCP serving front end: line-delimited JSON over a thread-pooled
//! listener, speaking the protocol in `protocol.rs`.

pub mod protocol;
pub mod tcp;

pub use protocol::{Request, Response, StreamStatus};
pub use tcp::Server;
