//! Ablation E8 — the runtime-dispatched XNOR-popcount microkernel tier.
//!
//! The paper's GPU kernels win by keeping the binarized operands in
//! registers and retiring one `__popc` per 32 channels; on CPU the
//! analogous levers are register tiling (MR=4 patch rows per weight
//! stream), Harley–Seal carry-save popcount (~1 `count_ones` retired
//! per 8 u64 lanes), and `std::arch` vector popcounts.  This ablation
//! forces each kernel tier through `microkernel::bgemm_with` /
//! `xorpop_words` on the network's three layer shapes at the serving
//! batch sizes, reporting img/s per (layer, kernel, batch) so the
//! dispatcher's default choice can be audited against measurement.
//!
//! Every tier is property-tested bit-identical to the seed scalar
//! kernels (`bnn::microkernel::tests`), so these rows differ only in
//! time, never in output.
//!
//!     cargo bench --bench ablation_microkernel

use bcnn::bnn::bgemm::widen_weights;
use bcnn::bnn::microkernel::{bgemm_with, xorpop_words};
use bcnn::platform::dispatch::{self, KernelKind};
use bcnn::util::rng::Xoshiro256;
use bcnn::util::timer::bench_for;
use std::time::Duration;

const MIN_TIME: Duration = Duration::from_millis(250);
const BATCHES: [usize; 3] = [1, 16, 64];

/// Kernels runnable on this host, scalar reference first so every
/// later row reads as a speedup over row one.
fn kernels() -> Vec<KernelKind> {
    let mut ks: Vec<KernelKind> =
        KernelKind::ALL.into_iter().filter(|k| k.available()).collect();
    ks.reverse();
    ks
}

/// One conv-layer GEMM shape: (M, KW) packed patches x (N, KW) weights.
struct GemmShape {
    label: &'static str,
    m: usize,
    n: usize,
    kw: usize,
    d: usize,
}

// conv1 rgb: 96x96 patches, 5*5*3 = 75-bit rows (L=2 after widening);
// conv2: 48x48 patches, 25 channel words (L=13, the long-K Harley-Seal
// target).  Both exactly the shapes `CompiledNetwork` executes.
const GEMMS: [GemmShape; 2] = [
    GemmShape { label: "conv1_rgb", m: 96 * 96, n: 32, kw: 3, d: 75 },
    GemmShape { label: "conv2", m: 48 * 48, n: 32, kw: 25, d: 800 },
];

fn gemm_tier(rng: &mut Xoshiro256) {
    for shape in GEMMS {
        let GemmShape { label, m, n, kw, d } = shape;
        let a: Vec<u32> = (0..m * kw).map(|_| rng.next_u32()).collect();
        let wt: Vec<u32> = (0..n * kw).map(|_| rng.next_u32()).collect();
        let w64 = widen_weights(&wt, n, kw);
        let mut out = vec![0i32; m * n];
        for kind in kernels() {
            for b in BATCHES {
                let stats = bench_for(MIN_TIME, 2, || {
                    for _ in 0..b {
                        bgemm_with(kind, &a, &w64, m, n, kw, d, &mut out);
                    }
                });
                let imgs = b as f64 / (stats.mean_ns * 1e-9);
                println!("{label}/{}/b{b}: {imgs:.1} img/s", kind.name());
            }
        }
    }
}

fn fc_tier(rng: &mut Xoshiro256) {
    // FC: 100 class rows of 576 packed words (18432 bits) per image —
    // the word-popcount consumer shape (`fc_packed_batch`'s inner dot)
    let (l, kw, d) = (100usize, 576usize, 18432usize);
    let max_b = *BATCHES.iter().max().unwrap();
    let xs: Vec<u32> = (0..max_b * kw).map(|_| rng.next_u32()).collect();
    let wt: Vec<u32> = (0..l * kw).map(|_| rng.next_u32()).collect();
    let mut sink = 0i64;
    for kind in kernels() {
        for b in BATCHES {
            let stats = bench_for(MIN_TIME, 4, || {
                for img in 0..b {
                    let x = &xs[img * kw..(img + 1) * kw];
                    for li in 0..l {
                        let pc = xorpop_words(kind, x, &wt[li * kw..(li + 1) * kw]);
                        sink += (d as i32 - 2 * pc as i32) as i64;
                    }
                }
            });
            let imgs = b as f64 / (stats.mean_ns * 1e-9);
            println!("fc/{}/b{b}: {imgs:.1} img/s", kind.name());
        }
    }
    assert_ne!(sink, i64::MIN); // keep the dots observable
}

fn main() {
    println!(
        "Microkernel ablation — dispatched default on this host: {}\n",
        dispatch::detect().name()
    );
    let mut rng = Xoshiro256::new(0xE8);
    gemm_tier(&mut rng);
    fc_tier(&mut rng);
    println!("\nrows are bit-identical by construction; only time varies.");
}
