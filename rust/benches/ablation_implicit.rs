//! Ablation E9 — explicit vs implicit GEMM for the binarized conv
//! (the paper's Section-5 future work: "implicit GEMM, which can be
//! faster than explicit GEMM").
//!
//! Explicit: gather the (H·W, K·K·NW) word-patch matrix, then bgemm.
//! Implicit: walk the window inline per output pixel (no patch matrix).
//!
//!     cargo bench --bench ablation_implicit

use bcnn::bnn::{bgemm, conv_direct, im2col};
use bcnn::util::rng::Xoshiro256;
use bcnn::util::timer::{bench_for, fmt_ns};
use std::time::Duration;

const MIN_TIME: Duration = Duration::from_millis(400);

fn main() {
    let mut rng = Xoshiro256::new(17);
    println!("Ablation E9 — explicit vs implicit GEMM (binarized conv, packed domain)\n");
    println!(
        "{:<26}{:>14}{:>14}{:>12}",
        "conv shape", "explicit", "implicit", "implicit-x"
    );
    // conv2 of the network (48,48,1 word) plus larger synthetic shapes to
    // show where the patch-matrix traffic starts to matter
    for (h, w, nw, o, label) in [
        (48usize, 48usize, 1usize, 32usize, "conv2 (48,48,32ch)"),
        (96, 96, 1, 32, "hi-res (96,96,32ch)"),
        (48, 48, 4, 32, "wide (48,48,128ch)"),
    ] {
        let k = 5;
        let d = k * k * nw * 32;
        let words: Vec<u32> = (0..h * w * nw).map(|_| rng.next_u32()).collect();
        let wt: Vec<u32> = (0..o * k * k * nw).map(|_| rng.next_u32()).collect();
        let explicit = bench_for(MIN_TIME, 8, || {
            let cols = im2col::im2col_words(&words, h, w, nw, k);
            bgemm::bgemm(&cols, &wt, h * w, o, k * k * nw, d)
        });
        let implicit = bench_for(MIN_TIME, 8, || {
            conv_direct::conv_packed_direct(&words, h, w, nw, &wt, o, k, d)
        });
        println!(
            "{:<26}{:>14}{:>14}{:>11.2}x",
            label,
            fmt_ns(explicit.mean_ns),
            fmt_ns(implicit.mean_ns),
            explicit.mean_ns / implicit.mean_ns
        );
    }
    println!("\nimplicit GEMM skips the K*K-fold patch-matrix store/reload; on GPU the");
    println!("paper expects a win (cuDNN's implicit GEMM ran its conv1 at 316µs vs 401µs).");
}
