//! Ablation E8 — pooling domain (our TPU/CPU adaptation, DESIGN.md §3).
//!
//! The paper pools in the float domain; because sign is monotone,
//! pooling AFTER binarization is a bitwise OR over packed words — 32
//! channels per instruction.  Compare:
//!   a. float max-pool of the (H,W,32) activation, then threshold+pack;
//!   b. threshold+pack first, then packed OR-pool.
//!
//!     cargo bench --bench ablation_orpool

use bcnn::bnn::{maxpool, packing};
use bcnn::util::rng::Xoshiro256;
use bcnn::util::timer::{bench_for, fmt_ns};
use std::time::Duration;

const MIN_TIME: Duration = Duration::from_millis(400);

fn threshold_pack(counts: &[f32], pixels: usize) -> Vec<u32> {
    let mut out = vec![0u32; pixels];
    for px in 0..pixels {
        let mut w = 0u32;
        for ch in 0..32 {
            w |= packing::threshold_bit(counts[px * 32 + ch], 0.0, 0) << (31 - ch);
        }
        out[px] = w;
    }
    out
}

fn main() {
    let mut rng = Xoshiro256::new(5);
    println!("Ablation E8 — pool-then-binarize vs binarize-then-OR-pool\n");
    println!(
        "{:<18}{:>16}{:>16}{:>10}",
        "shape", "float-pool path", "OR-pool path", "OR-x"
    );
    for (h, w) in [(96usize, 96usize), (48, 48)] {
        let counts: Vec<f32> = (0..h * w * 32).map(|_| rng.next_normal_f32() * 20.0).collect();
        // path a: float max-pool then threshold+pack
        let a = bench_for(MIN_TIME, 10, || {
            let pooled = maxpool::maxpool2x2(&counts, h, w, 32);
            threshold_pack(&pooled, h * w / 4)
        });
        // path b: threshold+pack then OR-pool
        let b = bench_for(MIN_TIME, 10, || {
            let words = threshold_pack(&counts, h * w);
            maxpool::orpool2x2(&words, h, w, 1)
        });
        // pure pooling-stage comparison (packing cost excluded)
        let words: Vec<u32> = (0..h * w).map(|_| rng.next_u32()).collect();
        let pool_f = bench_for(MIN_TIME, 10, || maxpool::maxpool2x2(&counts, h, w, 32));
        let pool_or = bench_for(MIN_TIME, 10, || maxpool::orpool2x2(&words, h, w, 1));
        println!(
            "{:<18}{:>16}{:>16}{:>9.2}x   (pool stage alone: {} vs {}, {:.1}x)",
            format!("({h},{w},32)"),
            fmt_ns(a.mean_ns),
            fmt_ns(b.mean_ns),
            a.mean_ns / b.mean_ns,
            fmt_ns(pool_f.mean_ns),
            fmt_ns(pool_or.mean_ns),
            pool_f.mean_ns / pool_or.mean_ns,
        );
    }
    println!("\n(identical bits either way — asserted by property tests; the OR-pool");
    println!(" touches 32x fewer bytes, which is the whole point)");
}
