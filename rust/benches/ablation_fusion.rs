//! Ablation E7 — fusion, at both tiers.
//!
//! **Kernel tier** (paper Section 3.1): the paper fuses patch
//! extraction and packing into one kernel, "reducing global memory
//! stores by K*K", and reports a further 2x from replacing div/mod
//! indexing with a counter.  On CPU the analogue is the materialized
//! float-patch matrix (the two-pass version writes and re-reads
//! 9216x75 floats).
//!
//! **Plan tier** (ISSUE 7): the proof-carrying rewriter fuses whole
//! plan steps — threshold into the popcount epilogue, binarize into
//! the im2col gather, counts buffer elided.  This ablation runs the
//! legacy rgb plan unrewritten, under each pass individually, and
//! under the full pipeline, at the batch sizes the serving plane uses,
//! reporting images/sec and the *proven* peak arena bytes from each
//! plan's `VerifyReport` (the same envelope `list_models` shows).
//!
//!     cargo bench --bench ablation_fusion

use bcnn::bnn::graph::{
    pass_names, rewrite_plan, verify_plan, CompiledNetwork, NetworkSpec, RewritePass,
};
use bcnn::bnn::im2col;
use bcnn::bnn::network::tests_support::{synth_bcnn_tf, synth_image};
use bcnn::bnn::scratch::PlanScratch;
use bcnn::input::binarize::Scheme;
use bcnn::util::rng::Xoshiro256;
use bcnn::util::timer::{bench_for, fmt_ns};
use std::time::Duration;

const MIN_TIME: Duration = Duration::from_millis(400);

fn kernel_tier() {
    let mut rng = Xoshiro256::new(9);
    println!("Kernel tier — fused im2col+pack vs two-pass (float patches then pack)\n");
    println!(
        "{:<22}{:>14}{:>14}{:>10}",
        "layer shape", "fused", "two-pass", "fused-x"
    );
    for (h, w, c, label) in [(96, 96, 3, "conv1 (96,96,3)"), (48, 48, 32, "conv2 (48,48,32)")] {
        let x: Vec<f32> = (0..h * w * c).map(|_| rng.next_pm1()).collect();
        let fused = bench_for(MIN_TIME, 10, || im2col::im2col_pack(&x, h, w, c, 5, 32));
        let twopass = bench_for(MIN_TIME, 10, || im2col::im2col_then_pack(&x, h, w, c, 5, 32));
        println!(
            "{:<22}{:>14}{:>14}{:>9.2}x",
            label,
            fmt_ns(fused.mean_ns),
            fmt_ns(twopass.mean_ns),
            twopass.mean_ns / fused.mean_ns
        );
    }
    println!("\npaper claim: fusion eliminates the K*K-fold patch-matrix store;");
    println!("our fused kernel keeps the patch in a register-resident scratch row.");
}

fn plan_tier() {
    let variants: [(&str, &[RewritePass]); 5] = [
        ("unrewritten", &[]),
        ("fold-threshold", &[RewritePass::FoldThreshold]),
        ("fuse-pack", &[RewritePass::FusePack]),
        ("fold+elide", &[RewritePass::FoldThreshold, RewritePass::ElideCounts]),
        ("all-passes", &RewritePass::ALL),
    ];
    let tf = synth_bcnn_tf(Scheme::Rgb, 700);
    let plan = NetworkSpec::legacy_bcnn(Scheme::Rgb).plan().unwrap();

    println!("\nPlan tier — proof-carrying rewrites on the legacy rgb plan");
    println!("(every variant passes check_equiv + verify_plan before running)\n");
    println!(
        "{:<16}{:>7}{:>11}{:>22}",
        "variant", "steps", "intervals", "peak bytes [f32/u32/i32]"
    );
    let mut nets = Vec::new();
    for (label, passes) in variants {
        let rw = rewrite_plan(&plan, passes);
        let report = verify_plan(&rw).unwrap_or_else(|e| panic!("{label}: unsound: {e}"));
        println!(
            "{:<16}{:>7}{:>11}{:>10}/{}/{}",
            label,
            report.steps,
            report.intervals,
            report.peak_bytes[0],
            report.peak_bytes[1],
            report.peak_bytes[2],
        );
        nets.push((label, CompiledNetwork::from_plan(rw, &tf).unwrap()));
    }

    const IMG: usize = 96 * 96 * 3;
    let batches = [1usize, 16, 64];
    let max_n = *batches.iter().max().unwrap();
    let pool: Vec<f32> = (0..max_n as u64).flat_map(synth_image).collect();

    println!();
    print!("{:<7}", "batch");
    for (label, _) in &nets {
        print!("{label:>16}");
    }
    println!("{:>9}", "all-x");
    for &bs in &batches {
        let payload = &pool[..bs * IMG];
        let mut means = Vec::new();
        print!("{bs:<7}");
        for (_, net) in &nets {
            let mut arena = PlanScratch::new();
            net.infer_batch_with(payload, &mut arena).unwrap(); // warm the slots
            let stats =
                bench_for(MIN_TIME, 4, || net.infer_batch_with(payload, &mut arena).unwrap());
            means.push(stats.mean_ns);
            print!("{:>16.1}", bs as f64 / (stats.mean_ns * 1e-9));
        }
        println!("{:>8.2}x", means[0] / means[means.len() - 1]);
    }
    println!(
        "\nfull pipeline = {} (what the loader serves when the gauntlet passes);",
        pass_names(&RewritePass::ALL)
    );
    println!("img/s columns; peak bytes are the statically proven per-image envelope.");
}

fn main() {
    kernel_tier();
    plan_tier();
}
