//! Ablation E7 — fused vs two-pass im2col+pack (paper Section 3.1).
//!
//! The paper fuses patch extraction and packing into one kernel,
//! "reducing global memory stores by K*K", and reports a further 2x from
//! replacing div/mod indexing with a counter.  On CPU the analogue is the
//! materialized float-patch matrix (the two-pass version writes and
//! re-reads 9216x75 floats).
//!
//!     cargo bench --bench ablation_fusion

use bcnn::bnn::im2col;
use bcnn::util::rng::Xoshiro256;
use bcnn::util::timer::{bench_for, fmt_ns};
use std::time::Duration;

const MIN_TIME: Duration = Duration::from_millis(400);

fn main() {
    let mut rng = Xoshiro256::new(9);
    println!("Ablation E7 — fused im2col+pack vs two-pass (float patches then pack)\n");
    println!(
        "{:<22}{:>14}{:>14}{:>10}",
        "layer shape", "fused", "two-pass", "fused-x"
    );
    for (h, w, c, label) in [(96, 96, 3, "conv1 (96,96,3)"), (48, 48, 32, "conv2 (48,48,32)")] {
        let x: Vec<f32> = (0..h * w * c).map(|_| rng.next_pm1()).collect();
        let fused = bench_for(MIN_TIME, 10, || im2col::im2col_pack(&x, h, w, c, 5, 32));
        let twopass = bench_for(MIN_TIME, 10, || im2col::im2col_then_pack(&x, h, w, c, 5, 32));
        println!(
            "{:<22}{:>14}{:>14}{:>9.2}x",
            label,
            fmt_ns(fused.mean_ns),
            fmt_ns(twopass.mean_ns),
            twopass.mean_ns / fused.mean_ns
        );
    }
    println!("\npaper claim: fusion eliminates the K*K-fold patch-matrix store;");
    println!("our fused kernel keeps the patch in a register-resident scratch row.");
}
