//! Plan ablation: images/sec through the layer-graph compiled forward
//! (ISSUE 5 tentpole) in its three execution postures, at the batch
//! sizes the serving plane actually uses:
//!
//! * **planned** — `CompiledNetwork::infer_batch_with` through a reused
//!   `PlanScratch` (the steady-state serving path: liveness-planned
//!   slots, zero intermediate allocation);
//! * **fresh** — the same compiled plan with a fresh arena per call
//!   (what the plan costs when nothing is pooled);
//! * **legacy loop** — the pre-refactor per-image protocol: one
//!   single-image forward per image (per-image kernel launches, no
//!   cross-image GEMM batching).
//!
//! Runs on synthetic weights, so no artifacts are required:
//!
//!     cargo bench --bench ablation_plan

use bcnn::bnn::network::tests_support::{synth_bcnn_network, synth_float_network, synth_image};
use bcnn::bnn::scratch::PlanScratch;
use bcnn::input::binarize::Scheme;
use bcnn::util::timer::bench;

fn main() {
    let batches = [1usize, 16, 64];
    let max_n = *batches.iter().max().unwrap();
    let pool: Vec<f32> = (0..max_n as u64).flat_map(synth_image).collect();
    const IMG: usize = 96 * 96 * 3;

    let bcnn = synth_bcnn_network(Scheme::Rgb, 501);
    let float = synth_float_network(502);

    println!("Layer-graph plan ablation — images/sec, compiled forward postures\n");
    println!(
        "{:<7}{:<7}{:>14}{:>14}{:>16}{:>9}",
        "net", "batch", "planned", "fresh", "legacy loop", "plan-x"
    );
    for &bs in &batches {
        let payload = &pool[..bs * IMG];
        let iters = (64 / bs).max(4);

        let mut arena = PlanScratch::new();
        bcnn.infer_batch_with(payload, &mut arena).unwrap(); // warm the slots
        let planned = bench(2, iters, || bcnn.infer_batch_with(payload, &mut arena).unwrap());
        let fresh = bench(2, iters, || bcnn.infer_batch(payload).unwrap());
        let loop_legacy = bench(2, iters, || {
            for i in 0..bs {
                bcnn.forward(&payload[i * IMG..(i + 1) * IMG]);
            }
        });
        let ips = |mean_ns: f64| bs as f64 / (mean_ns * 1e-9);
        println!(
            "{:<7}{:<7}{:>14.1}{:>14.1}{:>16.1}{:>8.2}x",
            "bcnn",
            bs,
            ips(planned.mean_ns),
            ips(fresh.mean_ns),
            ips(loop_legacy.mean_ns),
            loop_legacy.mean_ns / planned.mean_ns,
        );

        let f_iters = (iters / 2).max(2);
        let mut farena = PlanScratch::new();
        float.infer_batch_with(payload, &mut farena).unwrap();
        let planned =
            bench(1, f_iters, || float.infer_batch_with(payload, &mut farena).unwrap());
        let fresh = bench(1, f_iters, || float.infer_batch(payload).unwrap());
        let loop_legacy = bench(1, f_iters, || {
            for i in 0..bs {
                float.forward(&payload[i * IMG..(i + 1) * IMG]);
            }
        });
        println!(
            "{:<7}{:<7}{:>14.1}{:>14.1}{:>16.1}{:>8.2}x",
            "float",
            bs,
            ips(planned.mean_ns),
            ips(fresh.mean_ns),
            ips(loop_legacy.mean_ns),
            loop_legacy.mean_ns / planned.mean_ns,
        );
    }
    let mut probe = PlanScratch::new();
    bcnn.infer_batch_with(&pool[..IMG], &mut probe).unwrap();
    println!(
        "\nplanned arena for the rgb plan: {} slots, {} elements after warmup",
        probe.slot_counts().iter().sum::<usize>(),
        probe.capacity_elems(),
    );
    println!("(the plan compiler sizes the arena from per-edge liveness — see docs/ARCHITECTURE.md)");
}
