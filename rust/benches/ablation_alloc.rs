//! Allocation ablation: images/sec with the forward scratch arena OFF
//! (every `infer_batch` call allocates fresh intermediate tensors — the
//! PR 1 behavior) vs ON (a reused per-worker `PlanScratch` (the planned arena), the
//! steady-state serving configuration).  Both paths are bit-identical;
//! this bench isolates what allocator traffic alone costs at each batch
//! size.  Runs on synthetic weights, so no artifacts are required:
//!
//!     cargo bench --bench ablation_alloc

use bcnn::bnn::network::tests_support::{synth_bcnn_network, synth_float_network, synth_image};
use bcnn::bnn::scratch::PlanScratch;
use bcnn::input::binarize::Scheme;
use bcnn::util::timer::bench;

fn main() {
    let batches = [1usize, 4, 16, 64];
    let max_n = *batches.iter().max().unwrap();
    let pool: Vec<f32> = (0..max_n as u64).flat_map(synth_image).collect();
    const IMG: usize = 96 * 96 * 3;

    let bcnn = synth_bcnn_network(Scheme::Rgb, 201);
    let float = synth_float_network(202);

    println!("Scratch-arena ablation — images/sec, arena off (fresh buffers) vs on (reused)\n");
    println!(
        "{:<8}{:>14}{:>14}{:>8}{:>14}{:>14}{:>8}",
        "batch", "bcnn off", "bcnn on", "x", "float off", "float on", "x"
    );
    let mut b1_gain = 0.0;
    for &bs in &batches {
        let payload = &pool[..bs * IMG];
        let iters = (64 / bs).max(4);

        let mut bscratch = PlanScratch::new();
        // grow the arena to its high-water mark before measuring
        bcnn.infer_batch_with(payload, &mut bscratch).unwrap();
        let b_off = bench(2, iters, || bcnn.infer_batch(payload).unwrap());
        let b_on = bench(2, iters, || bcnn.infer_batch_with(payload, &mut bscratch).unwrap());

        let mut fscratch = PlanScratch::new();
        float.infer_batch_with(payload, &mut fscratch).unwrap();
        let f_iters = (iters / 2).max(2);
        let f_off = bench(1, f_iters, || float.infer_batch(payload).unwrap());
        let f_on =
            bench(1, f_iters, || float.infer_batch_with(payload, &mut fscratch).unwrap());

        let ips = |mean_ns: f64| bs as f64 / (mean_ns * 1e-9);
        if bs == 1 {
            b1_gain = b_off.mean_ns / b_on.mean_ns;
        }
        println!(
            "{:<8}{:>14.1}{:>14.1}{:>7.2}x{:>14.1}{:>14.1}{:>7.2}x",
            bs,
            ips(b_off.mean_ns),
            ips(b_on.mean_ns),
            b_off.mean_ns / b_on.mean_ns,
            ips(f_off.mean_ns),
            ips(f_on.mean_ns),
            f_off.mean_ns / f_on.mean_ns,
        );
    }
    println!(
        "\npacked engine at B=1 (the paper's real-time protocol): arena = {b1_gain:.2}x \
         (arena elements held: {})",
        {
            let mut s = PlanScratch::new();
            bcnn.infer_batch_with(&pool[..IMG], &mut s).unwrap();
            s.capacity_elems()
        }
    );
    println!("(arena off pays malloc/free for every im2col, GEMM, pack, pool, and fc buffer per call)");
}
