//! Ablation E5 — packing bitwidth B (paper Section 2.4 / 3.1).
//!
//! The paper packs B = 25 bits per word for the 5x5 patches (one word
//! per channel); B = 32 packs the flattened patch densely.  Sweep B over
//! {8, 16, 25, 32} on the conv1 and conv2 GEMMs: smaller B means more
//! words per patch (ceil(D/B)) and proportionally more xor+popcounts.
//!
//!     cargo bench --bench ablation_bitwidth

use bcnn::bnn::{bgemm, im2col, packing};
use bcnn::util::rng::Xoshiro256;
use bcnn::util::timer::{bench_for, fmt_ns};
use std::time::Duration;

const MIN_TIME: Duration = Duration::from_millis(300);

fn main() {
    let mut rng = Xoshiro256::new(42);
    let img: Vec<f32> = (0..96 * 96 * 3).map(|_| rng.next_pm1()).collect();
    let act2: Vec<f32> = (0..48 * 48 * 32).map(|_| rng.next_pm1()).collect();

    println!("Ablation E5 — packing bitwidth (conv GEMM + fused im2col+pack)\n");
    println!(
        "{:<8}{:>8}{:>8}{:>14}{:>14}{:>14}",
        "B", "KW1", "KW2", "pack1", "bgemm1", "bgemm2"
    );
    for b in [8usize, 16, 25, 32] {
        let kw1 = packing::packed_width(75, b);
        let kw2 = packing::packed_width(800, b);
        let cols1 = im2col::im2col_pack(&img, 96, 96, 3, 5, b);
        let cols2 = im2col::im2col_pack(&act2, 48, 48, 32, 5, b);
        let w1: Vec<u32> = (0..32 * kw1).map(|_| rng.next_u32()).collect();
        let w2: Vec<u32> = (0..32 * kw2).map(|_| rng.next_u32()).collect();
        // mask washes out: identical layouts on both operands, results
        // are layout-independent (asserted in bgemm unit tests)
        let pack = bench_for(MIN_TIME, 10, || im2col::im2col_pack(&img, 96, 96, 3, 5, b));
        let g1 = bench_for(MIN_TIME, 10, || bgemm::bgemm_bitwidth(&cols1, &w1, 9216, 32, kw1, 75));
        let g2 = bench_for(MIN_TIME, 10, || bgemm::bgemm_bitwidth(&cols2, &w2, 2304, 32, kw2, 800));
        println!(
            "{:<8}{:>8}{:>8}{:>14}{:>14}{:>14}",
            b,
            kw1,
            kw2,
            fmt_ns(pack.mean_ns),
            fmt_ns(g1.mean_ns),
            fmt_ns(g2.mean_ns)
        );
    }
    println!("\nexpected shape: bgemm cost scales with ceil(D/B); B=25 and B=32 tie for");
    println!("conv1 (both 3 words) while B=32 wins for conv2 (25 vs 32 words — the");
    println!("paper's per-channel B=25 layout trades density for indexing simplicity.");
}
