//! Ablation E6 — batching (paper Section 4: "cuDNN is optimized for
//! batch processing ... batch processing is not a suitable option for
//! real-time applications").
//!
//! Sweeps batch size over the engine backend and the batched HLO
//! executables, reporting per-sample latency and throughput: batching
//! amortizes fixed costs for the float net far more than for the BCNN,
//! which is the paper's implicit justification for single-sample timing.
//!
//!     cargo bench --bench ablation_batching

use bcnn::bnn::network::{BcnnNetwork, FloatNetwork};
use bcnn::coordinator::backend::{EngineBackend, InferBackend};
use bcnn::dataset::synth;
use bcnn::input::binarize::Scheme;
use bcnn::runtime::{Artifacts, ModelRuntime};
use bcnn::util::timer::{bench, fmt_ns};

fn main() {
    if !std::path::Path::new("artifacts/manifest.json").exists() {
        println!("artifacts/ missing — run `make artifacts` first");
        return;
    }
    let artifacts = Artifacts::load("artifacts").unwrap();
    let batches = [1usize, 4, 16, 64];

    // pre-render a pool of images
    let pool: Vec<f32> = (0..64)
        .flat_map(|i| synth::render_vehicle(i, synth::DEFAULT_SEED).image)
        .collect();

    // --- engine backends (threads = batch parallelism) ---------------------
    println!("Ablation E6 — batching (per-sample latency / throughput)\n");
    println!("[engine backends, parallel across cores]");
    println!(
        "{:<10}{:>16}{:>14}{:>16}{:>14}",
        "batch", "float/sample", "float req/s", "bcnn/sample", "bcnn req/s"
    );
    let threads = bcnn::util::threadpool::default_threads();
    let float_be = EngineBackend::float(
        FloatNetwork::load(artifacts.path_of("weights_float.bcnt")).unwrap(),
        threads,
    );
    let bcnn_be = EngineBackend::bcnn(
        BcnnNetwork::load(artifacts.path_of("weights_bcnn_rgb.bcnt"), Scheme::Rgb).unwrap(),
        threads,
    );
    for &bs in &batches {
        let payload = &pool[..bs * 96 * 96 * 3];
        let f = bench(3, 30, || float_be.infer_batch(payload).unwrap());
        let b = bench(3, 30, || bcnn_be.infer_batch(payload).unwrap());
        println!(
            "{:<10}{:>16}{:>14.0}{:>16}{:>14.0}",
            bs,
            fmt_ns(f.mean_ns / bs as f64),
            bs as f64 / (f.mean_ns * 1e-9),
            fmt_ns(b.mean_ns / bs as f64),
            bs as f64 / (b.mean_ns * 1e-9),
        );
    }

    // --- HLO executables (XLA's own batching) -------------------------------
    println!("\n[AOT HLO on PJRT CPU — XLA batches internally]");
    println!(
        "{:<10}{:>16}{:>14}{:>16}{:>14}{:>12}",
        "batch", "float/sample", "float req/s", "bcnn/sample", "bcnn req/s", "bcnn-x"
    );
    let client = bcnn::runtime::client::cpu_client().unwrap();
    for &bs in &batches {
        let float_rt = ModelRuntime::load(&client, &artifacts, &format!("model_float_b{bs}")).unwrap();
        let bcnn_rt =
            ModelRuntime::load(&client, &artifacts, &format!("model_bcnn_rgb_ref_b{bs}")).unwrap();
        let payload = &pool[..bs * 96 * 96 * 3];
        let f = bench(3, 30, || float_rt.infer(payload).unwrap());
        let b = bench(3, 30, || bcnn_rt.infer(payload).unwrap());
        println!(
            "{:<10}{:>16}{:>14.0}{:>16}{:>14.0}{:>11.2}x",
            bs,
            fmt_ns(f.mean_ns / bs as f64),
            bs as f64 / (f.mean_ns * 1e-9),
            fmt_ns(b.mean_ns / bs as f64),
            bs as f64 / (b.mean_ns * 1e-9),
            f.mean_ns / b.mean_ns,
        );
    }
    println!("\npaper context: their Table 1 is batch-1 by design; the sweep shows how");
    println!("much of the float baseline's deficit batching recovers.");
}
