//! Table 2 reproduction: per-layer runtime, full-precision vs binarized.
//!
//! Measures the Rust engine's kernels at the paper's exact layer shapes
//! and, when artifacts are present, the AOT HLO per-layer executables on
//! PJRT.  Prints the paper's numbers alongside for shape comparison
//! (absolute µs differ — GTX 1080 vs this CPU — the *ratios* are the
//! reproduced claim).
//!
//!     cargo bench --bench table2_layers

use std::time::Duration;

use bcnn::bnn::{bgemm, fc, float_ops, im2col, maxpool};
use bcnn::runtime::client::{cpu_client, LayerArg, LayerRuntime};
use bcnn::runtime::Artifacts;
use bcnn::util::rng::Xoshiro256;
use bcnn::util::timer::{bench_for, fmt_ns};

const MIN_TIME: Duration = Duration::from_millis(300);

/// (layer, paper cuDNN µs, paper binarized µs)
const PAPER: [(&str, f64, f64); 7] = [
    ("im2col1 (96,96,3)", 21.63, 3.17),
    ("gemm1 (32,5,5,3)", 37.54, 8.61),
    ("pool1 (96,96,32)", 5.22, 8.26),
    ("im2col2 (48,48,32)", 65.41, 5.50),
    ("gemm2 (32,5,5,32)", 69.28, 8.10),
    ("pool2 (48,48,32)", 5.38, 2.66),
    ("fc (100,18432)", 200.03, 6.28),
];

fn main() {
    let mut rng = Xoshiro256::new(0xBEEF);

    // --- inputs at the paper's layer shapes ------------------------------
    let img1: Vec<f32> = (0..96 * 96 * 3).map(|_| rng.next_pm1()).collect();
    let act1: Vec<f32> = (0..96 * 96 * 32).map(|_| rng.next_normal_f32()).collect();
    let act2f: Vec<f32> = (0..48 * 48 * 32).map(|_| rng.next_pm1()).collect();
    let cols1f = im2col::im2col_float(&img1, 96, 96, 3, 5);
    let cols2f = im2col::im2col_float(&act2f, 48, 48, 32, 5);
    let w1f: Vec<f32> = (0..32 * 75).map(|_| rng.next_normal_f32()).collect();
    let w2f: Vec<f32> = (0..32 * 800).map(|_| rng.next_normal_f32()).collect();
    let wfcf: Vec<f32> = (0..100 * 18432).map(|_| rng.next_normal_f32()).collect();
    let xfcf: Vec<f32> = (0..18432).map(|_| rng.next_normal_f32()).collect();

    let cols1b = im2col::im2col_pack(&img1, 96, 96, 3, 5, 32);
    let cols2b = im2col::im2col_pack(&act2f, 48, 48, 32, 5, 32);
    let w1b: Vec<u32> = (0..32 * 3).map(|_| rng.next_u32()).collect();
    let w2b: Vec<u32> = (0..32 * 25).map(|_| rng.next_u32()).collect();
    let words1: Vec<u32> = (0..96 * 96).map(|_| rng.next_u32()).collect();
    let words2: Vec<u32> = (0..48 * 48).map(|_| rng.next_u32()).collect();
    let xfcb: Vec<u32> = (0..576).map(|_| rng.next_u32()).collect();
    let wfcb: Vec<u32> = (0..100 * 576).map(|_| rng.next_u32()).collect();

    // --- measure the engine ------------------------------------------------
    let rows: Vec<(usize, f64, f64)> = vec![
        // (paper row index, float ns, binarized ns)
        (0, bench_for(MIN_TIME, 20, || im2col::im2col_float(&img1, 96, 96, 3, 5)).mean_ns,
            bench_for(MIN_TIME, 20, || im2col::im2col_pack(&img1, 96, 96, 3, 5, 32)).mean_ns),
        (1, bench_for(MIN_TIME, 20, || float_ops::gemm_blocked(&cols1f, &w1f, 9216, 32, 75)).mean_ns,
            bench_for(MIN_TIME, 20, || bgemm::bgemm(&cols1b, &w1b, 9216, 32, 3, 75)).mean_ns),
        (2, bench_for(MIN_TIME, 20, || maxpool::maxpool2x2(&act1, 96, 96, 32)).mean_ns,
            bench_for(MIN_TIME, 20, || maxpool::orpool2x2(&words1, 96, 96, 1)).mean_ns),
        (3, bench_for(MIN_TIME, 20, || im2col::im2col_float(&act2f, 48, 48, 32, 5)).mean_ns,
            bench_for(MIN_TIME, 20, || im2col::im2col_words(&words2, 48, 48, 1, 5)).mean_ns),
        (4, bench_for(MIN_TIME, 20, || float_ops::gemm_blocked(&cols2f, &w2f, 2304, 32, 800)).mean_ns,
            bench_for(MIN_TIME, 20, || {
                let cols = im2col::im2col_words(&words2, 48, 48, 1, 5);
                bgemm::bgemm(&cols, &w2b, 2304, 32, 25, 800)
            }).mean_ns),
        (5, bench_for(MIN_TIME, 20, || maxpool::maxpool2x2(&act2f, 48, 48, 32)).mean_ns,
            bench_for(MIN_TIME, 20, || maxpool::orpool2x2(&words2, 48, 48, 1)).mean_ns),
        (6, bench_for(MIN_TIME, 20, || fc::fc_float(&xfcf, &wfcf, 100, 18432)).mean_ns,
            bench_for(MIN_TIME, 20, || fc::fc_packed(&xfcb, &wfcb, 100, 576, 18432)).mean_ns),
    ];

    println!("\nTable 2 — per-layer runtime (Rust engine on this CPU vs paper GTX 1080)");
    println!(
        "{:<22}{:>12}{:>12}{:>9}   {:>12}{:>12}{:>9}",
        "layer", "float", "binarized", "speedup", "paper-cuDNN", "paper-bin", "paper-x"
    );
    let (mut tot_f, mut tot_b) = (0.0, 0.0);
    for (i, f_ns, b_ns) in &rows {
        let (name, pf, pb) = PAPER[*i];
        tot_f += f_ns;
        tot_b += b_ns;
        println!(
            "{:<22}{:>12}{:>12}{:>8.2}x   {:>10.2}µs{:>10.2}µs{:>8.2}x",
            name,
            fmt_ns(*f_ns),
            fmt_ns(*b_ns),
            f_ns / b_ns,
            pf,
            pb,
            pf / pb
        );
    }
    println!(
        "{:<22}{:>12}{:>12}{:>8.2}x   {:>10.2}µs{:>10.2}µs{:>8.2}x",
        "TOTAL",
        fmt_ns(tot_f),
        fmt_ns(tot_b),
        tot_f / tot_b,
        404.49,
        42.58,
        404.49 / 42.58
    );

    // note: binarized gemm2 includes its word-gather (conv2's im2col is
    // nearly free in the packed domain; the paper reports them separately)

    // --- HLO per-layer executables on PJRT ---------------------------------
    if !std::path::Path::new("artifacts/manifest.json").exists() {
        println!("\n(artifacts missing — skipping PJRT layer benches; run `make artifacts`)");
        return;
    }
    let artifacts = Artifacts::load("artifacts").unwrap();
    let client = cpu_client().unwrap();
    println!("\nAOT HLO per-layer executables (PJRT CPU; includes dispatch overhead)");
    println!("{:<26}{:>14}", "artifact", "mean");
    for pair in [
        ["layer_im2col1_float", "layer_im2col1_bin"],
        ["layer_gemm1_float", "layer_bgemm1"],
        ["layer_pool1_float", "layer_pool1_or"],
        ["layer_im2col2_float", "layer_im2col2_bin"],
        ["layer_gemm2_float", "layer_bgemm2"],
        ["layer_pool2_float", "layer_pool2_or"],
        ["layer_fc_float", "layer_fc_packed"],
    ] {
        for name in pair {
            let mut rng = Xoshiro256::new(7);
            let rt = LayerRuntime::load(&client, &artifacts, name, |_, spec| {
                LayerArg::random(spec, &mut rng)
            })
            .unwrap();
            let stats = bench_for(MIN_TIME, 20, || rt.run().unwrap());
            println!("{:<26}{:>14}", name, fmt_ns(stats.mean_ns));
        }
    }
    println!("\n(note: interpret-mode Pallas artifacts lower grids to HLO while-loops;");
    println!(" the Rust engine numbers above are the performance-representative ones)");
}
