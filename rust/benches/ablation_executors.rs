//! Multi-executor lane ablation: end-to-end coordinator throughput
//! (images/sec through router + queue + batcher + backend) as the
//! executor pool grows, at each batch-formation size.
//!
//! The backend runs with ONE engine thread per batch so the curve
//! isolates what cross-batch concurrency alone buys: executors=1 is the
//! pre-PR-3 serial lane (batch formation and execution alternate),
//! executors=N overlaps them.  Expect near-linear scaling at small B
//! (execution dominates, batches are independent) flattening once
//! executors × B saturate the host's cores — and no benefit past
//! `platform::profiles::MAX_AUTO_EXECUTORS` by design.  Runs on
//! synthetic weights, so no artifacts are required:
//!
//!     cargo bench --bench ablation_executors
//!
//! Record the table in docs/ARCHITECTURE.md when re-running on a new
//! host (see "Multi-executor ablation" there).

use std::sync::Arc;

use bcnn::bnn::network::tests_support::{synth_bcnn_network, synth_image};
use bcnn::coordinator::{BatchPolicy, EngineBackend, InferBackend, Router};
use bcnn::input::binarize::Scheme;

const IMG: usize = 96 * 96 * 3;
const TOTAL_IMAGES: usize = 256;

fn run_once(executors: usize, max_batch: usize, pool: &[f32]) -> f64 {
    let be: Arc<dyn InferBackend> =
        Arc::new(EngineBackend::bcnn(synth_bcnn_network(Scheme::Rgb, 301), 1));
    let router = Router::builder()
        .policy(BatchPolicy {
            max_batch,
            max_wait: std::time::Duration::from_micros(200),
            executors,
        })
        .queue_capacity(TOTAL_IMAGES * 2)
        .variant("rgb", be)
        .build();
    // warm the arenas and code paths
    let _ = router.infer_blocking("rgb", pool[..IMG].to_vec());
    let started = std::time::Instant::now();
    let mut rxs = Vec::with_capacity(TOTAL_IMAGES);
    for i in 0..TOTAL_IMAGES {
        let img = pool[i * IMG..(i + 1) * IMG].to_vec();
        rxs.push(router.submit("rgb", img).expect("admission").1);
    }
    for rx in rxs {
        let resp = rx.recv().expect("lane alive");
        assert!(resp.error.is_none(), "{:?}", resp.error);
    }
    let secs = started.elapsed().as_secs_f64();
    router.shutdown();
    TOTAL_IMAGES as f64 / secs
}

fn main() {
    let pool: Vec<f32> = (0..TOTAL_IMAGES as u64).flat_map(synth_image).collect();
    let executor_counts = [1usize, 2, 4, 8];
    let batch_sizes = [1usize, 16, 64];

    println!(
        "Multi-executor lane ablation — images/sec over {TOTAL_IMAGES} requests \
         (engine threads per batch = 1)\n"
    );
    print!("{:<12}", "executors");
    for &b in &batch_sizes {
        print!("{:>12}", format!("B={b}"));
    }
    println!("{:>12}", "B=1 spdup");
    let mut serial_b1 = 0.0;
    for &e in &executor_counts {
        print!("{e:<12}");
        let mut b1 = 0.0;
        for &b in &batch_sizes {
            let ips = run_once(e, b, &pool);
            if b == 1 {
                b1 = ips;
                if e == 1 {
                    serial_b1 = ips;
                }
            }
            print!("{ips:>12.1}");
        }
        println!("{:>11.2}x", b1 / serial_b1);
    }
    println!(
        "\nexecutors=1 is the serial lane (batch formation and execution alternate);\n\
         logits are bit-identical across every cell (integration-tested)."
    );
}
