//! Table 1 reproduction: whole-network runtime per implementation.
//!
//! Three views:
//!  1. measured — the Rust engine (this testbed's "GPU kernels"):
//!     full-precision vs BCNN vs BCNN-with-binarized-inputs, using the
//!     paper's protocol (1000 single-sample inferences, kernel time only);
//!  2. measured — the AOT HLO executables on PJRT (float = XLA's own
//!     conv stack, i.e. the vendor-library role);
//!  3. modelled — the analytical platform projections for GTX 1080 /
//!     Mali T860 / Tegra X2 (DESIGN.md §2 substitution).
//!
//!     cargo bench --bench table1_e2e

use std::time::Duration;

use bcnn::bnn::network::{BcnnNetwork, FloatNetwork};
use bcnn::dataset::synth;
use bcnn::input::binarize::Scheme;
use bcnn::runtime::{Artifacts, ModelRuntime};
use bcnn::util::timer::{bench, fmt_ns};

const SAMPLES: usize = 1000;

fn main() {
    let has_artifacts = std::path::Path::new("artifacts/manifest.json").exists();
    if !has_artifacts {
        println!("artifacts/ missing — run `make artifacts` first");
        return;
    }
    let artifacts = Artifacts::load("artifacts").unwrap();

    // the paper's protocol: 1000 random images, one at a time
    let images: Vec<Vec<f32>> = (0..SAMPLES.min(64))
        .map(|i| synth::render_vehicle(i, synth::DEFAULT_SEED).image)
        .collect();
    let mut idx = 0usize;
    let mut next = || {
        idx = (idx + 1) % images.len();
        &images[idx]
    };

    println!("Table 1 — whole-network runtime ({SAMPLES} single-sample inferences)\n");

    // --- view 1: the Rust engine -----------------------------------------
    let float_net = FloatNetwork::load(artifacts.path_of("weights_float.bcnt")).unwrap();
    let bcnn_none = BcnnNetwork::load(artifacts.path_of("weights_bcnn_none.bcnt"), Scheme::None).unwrap();
    let bcnn_rgb = BcnnNetwork::load(artifacts.path_of("weights_bcnn_rgb.bcnt"), Scheme::Rgb).unwrap();

    let f = bench(20, SAMPLES, || float_net.forward(next()));
    let b_none = bench(20, SAMPLES, || bcnn_none.forward(next()));
    let b_rgb = bench(20, SAMPLES, || bcnn_rgb.forward(next()));

    println!("[engine — this CPU]");
    println!("{:<34}{:>12}{:>10}", "implementation", "mean", "speedup");
    println!("{:<34}{:>12}{:>10}", "full-precision", fmt_ns(f.mean_ns), "1.00x");
    println!(
        "{:<34}{:>12}{:>9.2}x",
        "BCNN (float first layer)",
        fmt_ns(b_none.mean_ns),
        f.mean_ns / b_none.mean_ns
    );
    println!(
        "{:<34}{:>12}{:>9.2}x",
        "BCNN with binarized inputs (rgb)",
        fmt_ns(b_rgb.mean_ns),
        f.mean_ns / b_rgb.mean_ns
    );

    // --- view 2: HLO executables on PJRT ------------------------------------
    let client = bcnn::runtime::client::cpu_client().unwrap();
    let float_rt = ModelRuntime::load(&client, &artifacts, "model_float_b1").unwrap();
    let none_rt = ModelRuntime::load(&client, &artifacts, "model_bcnn_none_ref_b1").unwrap();
    let rgb_rt = ModelRuntime::load(&client, &artifacts, "model_bcnn_rgb_ref_b1").unwrap();
    let hf = bench(10, 200, || float_rt.infer(next()).unwrap());
    let hn = bench(10, 200, || none_rt.infer(next()).unwrap());
    let hr = bench(10, 200, || rgb_rt.infer(next()).unwrap());
    println!("\n[AOT HLO on PJRT CPU — float path = XLA's vendor conv stack]");
    println!("{:<34}{:>12}{:>10}", "implementation", "mean", "speedup");
    println!("{:<34}{:>12}{:>10}", "full-precision (XLA conv)", fmt_ns(hf.mean_ns), "1.00x");
    println!(
        "{:<34}{:>12}{:>9.2}x",
        "BCNN (float first layer)",
        fmt_ns(hn.mean_ns),
        hf.mean_ns / hn.mean_ns
    );
    println!(
        "{:<34}{:>12}{:>9.2}x",
        "BCNN with binarized inputs (rgb)",
        fmt_ns(hr.mean_ns),
        hf.mean_ns / hr.mean_ns
    );

    // --- view 3: the analytical platform model ------------------------------
    println!();
    bcnn::platform::print_table1_projection();

    println!("\npaper Table 1 (for shape comparison):");
    println!("  GTX 1080:  cuDNN 401.83 µs | BCNN 102.39 µs (3.9x) | BCNN+bin-inputs 55.63 µs (7.2x)");
    println!("  Mali T860: ArmCL 29.61 ms  | BCNN 23.63 ms (1.25x) | BCNN+bin-inputs 17.58 ms (1.7x)");
    println!("  Tegra X2:  cuDNN 2.27 ms   | BCNN 0.53 ms  (4.3x)  | BCNN+bin-inputs 0.41 ms (5.5x)");

    let _ = Duration::ZERO;
}
