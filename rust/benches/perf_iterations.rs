//! §Perf iteration log (EXPERIMENTS.md): each optimization step kept in
//! benchable form so before/after is reproducible.
//!
//!   bgemm v0 — per-(m,n) slicing, u64 pairing per weight row per patch
//!              (the first implementation; its original pointer-cast
//!              widening was replaced by the same safe shift+or fuse the
//!              library now uses — identical loop structure and counts)
//!   bgemm v1 — operands widened to padded u64 rows once, fixed-lane
//!              inner kernels (shipped in bnn::bgemm)
//!   pack  v0 — patch scratch buffer + div/mod packing (two-pass; kept
//!              as bnn::im2col::im2col_then_pack for the E7 ablation)
//!   pack  v1 — Algorithm-1 bit-writer, fused (shipped)
//!
//!     cargo bench --bench perf_iterations

use bcnn::bnn::{bgemm, im2col};
use bcnn::util::rng::Xoshiro256;
use bcnn::util::timer::{bench_for, fmt_ns};
use std::time::Duration;

const MIN_TIME: Duration = Duration::from_millis(400);

/// The original bgemm inner loop (v0): per-(m,n) row slicing with u64
/// pairing done afresh for every row pair.
fn bgemm_v0(a: &[u32], wt: &[u32], m: usize, n: usize, kw: usize, d_real: usize) -> Vec<i32> {
    let fuse = |hi: u32, lo: u32| (u64::from(hi) << 32) | u64::from(lo);
    let mut out = vec![0i32; m * n];
    let d = d_real as i32;
    for mi in 0..m {
        let arow = &a[mi * kw..(mi + 1) * kw];
        let orow = &mut out[mi * n..(mi + 1) * n];
        for ni in 0..n {
            let wrow = &wt[ni * kw..(ni + 1) * kw];
            let a2 = arow.chunks_exact(2);
            let w2 = wrow.chunks_exact(2);
            let mut pc: u32 = match (a2.remainder(), w2.remainder()) {
                (&[x], &[y]) => (x ^ y).count_ones(),
                _ => 0,
            };
            for (p, q) in a2.zip(w2) {
                pc += (fuse(p[0], p[1]) ^ fuse(q[0], q[1])).count_ones();
            }
            orow[ni] = d - 2 * pc as i32;
        }
    }
    out
}

fn main() {
    let mut rng = Xoshiro256::new(3);
    println!("§Perf kernel iterations (quiet-machine, single core)\n");

    for (label, m, n, kw, d) in [
        ("conv1 bgemm (9216x32, KW=3)", 9216usize, 32usize, 3usize, 75usize),
        ("conv2 bgemm (2304x32, KW=25)", 2304, 32, 25, 800),
        ("fc-as-gemm (1x100, KW=576)", 1, 100, 576, 18432),
    ] {
        let a: Vec<u32> = (0..m * kw).map(|_| rng.next_u32()).collect();
        let w: Vec<u32> = (0..n * kw).map(|_| rng.next_u32()).collect();
        // correctness guard: both generations agree
        assert_eq!(bgemm_v0(&a, &w, m, n, kw, d), bgemm::bgemm(&a, &w, m, n, kw, d));
        let v0 = bench_for(MIN_TIME, 10, || bgemm_v0(&a, &w, m, n, kw, d));
        let v1 = bench_for(MIN_TIME, 10, || bgemm::bgemm(&a, &w, m, n, kw, d));
        println!(
            "{label:<32} v0 {:>12}   v1 {:>12}   {:.2}x",
            fmt_ns(v0.mean_ns),
            fmt_ns(v1.mean_ns),
            v0.mean_ns / v1.mean_ns
        );
    }

    println!();
    for (label, h, w, c) in [("im2col+pack conv1 (96,96,3)", 96usize, 96usize, 3usize), ("im2col+pack conv2 (48,48,32)", 48, 48, 32)] {
        let x: Vec<f32> = (0..h * w * c).map(|_| rng.next_pm1()).collect();
        assert_eq!(
            im2col::im2col_then_pack(&x, h, w, c, 5, 32),
            im2col::im2col_pack(&x, h, w, c, 5, 32)
        );
        let v0 = bench_for(MIN_TIME, 10, || im2col::im2col_then_pack(&x, h, w, c, 5, 32));
        let v1 = bench_for(MIN_TIME, 10, || im2col::im2col_pack(&x, h, w, c, 5, 32));
        println!(
            "{label:<32} v0 {:>12}   v1 {:>12}   {:.2}x",
            fmt_ns(v0.mean_ns),
            fmt_ns(v1.mean_ns),
            v0.mean_ns / v1.mean_ns
        );
    }
}
