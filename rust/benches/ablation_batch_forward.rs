//! Batched-forward ablation: images/sec vs batch size for the pure-Rust
//! engine, float and packed — the tentpole measurement for the batched
//! end-to-end path.
//!
//! The single-image protocol (the paper's Section 2.2) pays the A-operand
//! repack, the weight widening, and every intermediate allocation once
//! per image; `infer_batch` pays them once per batch with
//! M = batch × spatial positions, keeping the packed weight rows L1-hot
//! across all images (the bit-level-parallelism-at-small-batch gap BSTC
//! and FINN point out).  Runs on synthetic weights, so no artifacts are
//! required:
//!
//!     cargo bench --bench ablation_batch_forward

use bcnn::bnn::network::tests_support::{synth_bcnn_network, synth_float_network, synth_image};
use bcnn::input::binarize::Scheme;
use bcnn::util::timer::{bench, fmt_ns};

fn main() {
    let batches = [1usize, 4, 16, 64];
    let max_n = *batches.iter().max().unwrap();
    let pool: Vec<f32> = (0..max_n as u64).flat_map(synth_image).collect();
    const IMG: usize = 96 * 96 * 3;

    let bcnn = synth_bcnn_network(Scheme::Rgb, 101);
    let float = synth_float_network(102);

    println!("Batched forward — images/sec vs batch size (single-core engine)\n");
    println!(
        "{:<8}{:>18}{:>14}{:>18}{:>14}{:>12}",
        "batch", "bcnn/sample", "bcnn img/s", "float/sample", "float img/s", "bcnn-x"
    );
    let mut bcnn_ips = Vec::new();
    for &bs in &batches {
        let payload = &pool[..bs * IMG];
        // fewer measured iters at large batch keeps wall time flat
        let iters = (64 / bs).max(4);
        let b = bench(2, iters, || bcnn.infer_batch(payload).unwrap());
        let f = bench(1, (iters / 2).max(2), || float.infer_batch(payload).unwrap());
        let b_ips = bs as f64 / (b.mean_ns * 1e-9);
        let f_ips = bs as f64 / (f.mean_ns * 1e-9);
        bcnn_ips.push((bs, b_ips));
        println!(
            "{:<8}{:>18}{:>14.1}{:>18}{:>14.1}{:>11.2}x",
            bs,
            fmt_ns(b.mean_ns / bs as f64),
            b_ips,
            fmt_ns(f.mean_ns / bs as f64),
            f_ips,
            f.mean_ns / b.mean_ns,
        );
    }

    let b1 = bcnn_ips.iter().find(|(bs, _)| *bs == 1).unwrap().1;
    let b16 = bcnn_ips.iter().find(|(bs, _)| *bs == 16).unwrap().1;
    println!(
        "\npacked engine: batch 16 throughput = {:.2}x batch 1 ({b16:.1} vs {b1:.1} img/s)",
        b16 / b1
    );
    println!("(amortized per batch: weight widening, fused repack setup, allocations)");
}
