//! End-to-end registry lifecycle over TCP: versioned entries from a
//! models directory, admin ops, and hot version swaps under live
//! streaming traffic — the acceptance test for the hot-swappable
//! registry (ISSUE 4).

use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::Duration;

use bcnn::bnn::graph::{CompiledNetwork, NetworkSpec};
use bcnn::bnn::network::tests_support::{synth_bcnn_tf, synth_float_tf, synth_tf_for_spec};
use bcnn::coordinator::BatchPolicy;
use bcnn::input::binarize::Scheme;
use bcnn::registry::{fnv1a64, format_checksum, ModelRegistry};
use bcnn::server::Server;
use bcnn::util::json::Json;

/// Write a models directory holding bcnn v1 + v2 (different weights)
/// and float v1, with a registry.json carrying real checksums.
fn write_models_dir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("bcnn-reg-e2e-{tag}-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    synth_bcnn_tf(Scheme::Rgb, 1001).save(dir.join("bcnn_v1.bcnt")).unwrap();
    synth_bcnn_tf(Scheme::Rgb, 1002).save(dir.join("bcnn_v2.bcnt")).unwrap();
    synth_float_tf(1003).save(dir.join("float_v1.bcnt")).unwrap();
    let sum = |f: &str| format_checksum(fnv1a64(&std::fs::read(dir.join(f)).unwrap()));
    let manifest = format!(
        r#"{{"version": 1, "default": "bcnn", "models": [
  {{"name": "bcnn", "version": 1, "kind": "bcnn", "scheme": "rgb",
    "weights_file": "bcnn_v1.bcnt", "checksum": "{}"}},
  {{"name": "bcnn", "version": 2, "kind": "bcnn", "scheme": "rgb",
    "weights_file": "bcnn_v2.bcnt", "checksum": "{}"}},
  {{"name": "float", "version": 1, "kind": "float", "scheme": "float",
    "weights_file": "float_v1.bcnt", "checksum": "{}"}}
]}}"#,
        sum("bcnn_v1.bcnt"),
        sum("bcnn_v2.bcnt"),
        sum("float_v1.bcnt"),
    );
    std::fs::write(dir.join("registry.json"), manifest).unwrap();
    dir
}

/// Start a server with bcnn@1 + float@1 resident (bcnn default);
/// bcnn@2 stays on disk for the hot load.
fn start_server(dir: &Path) -> (std::net::SocketAddr, Arc<AtomicBool>) {
    start_server_with(dir, None)
}

fn start_server_with(
    dir: &Path,
    admin_token: Option<&str>,
) -> (std::net::SocketAddr, Arc<AtomicBool>) {
    let registry = ModelRegistry::builder()
        .policy(BatchPolicy {
            max_batch: 4,
            max_wait: Duration::from_micros(200),
            executors: 2,
        })
        .queue_capacity(512)
        .engine_threads(1)
        .models_dir(dir)
        .build();
    registry.load_model("bcnn", 1).unwrap();
    registry.load_model("float", 1).unwrap();
    registry.set_default("bcnn", Some(1)).unwrap();
    let server = Arc::new(
        Server::new(
            registry,
            vec!["bus".into(), "normal".into(), "truck".into(), "van".into()],
        )
        .with_admin_token(admin_token.map(str::to_string)),
    );
    let stop = Arc::new(AtomicBool::new(false));
    let addr = Arc::clone(&server).serve("127.0.0.1:0", 4, Arc::clone(&stop)).unwrap();
    (addr, stop)
}

struct Client {
    conn: TcpStream,
    reader: BufReader<TcpStream>,
}

impl Client {
    fn connect(addr: std::net::SocketAddr) -> Self {
        let conn = TcpStream::connect(addr).unwrap();
        let reader = BufReader::new(conn.try_clone().unwrap());
        Self { conn, reader }
    }

    fn roundtrip(&mut self, req: &str) -> Json {
        self.conn.write_all(req.as_bytes()).unwrap();
        self.conn.write_all(b"\n").unwrap();
        self.read_line()
    }

    fn read_line(&mut self) -> Json {
        let mut line = String::new();
        self.reader.read_line(&mut line).unwrap();
        Json::parse(&line).expect(&line)
    }
}

fn one_image_json() -> String {
    let px = vec!["0.5"; 96 * 96 * 3].join(",");
    format!("[{px}]")
}

#[test]
fn hot_swap_under_streaming_load_drops_nothing_and_reports_versions() {
    let dir = write_models_dir("hotswap");
    let (addr, stop) = start_server(&dir);
    let mut a = Client::connect(addr);

    // --- acceptance: two entries servable concurrently over ONE conn ---
    let img = one_image_json();
    let r = a.roundtrip(&format!(r#"{{"op":"classify","model":"bcnn","pixels":{img}}}"#));
    assert!(r.get("ok").unwrap().as_bool().unwrap(), "{r}");
    assert_eq!(r.get("model").unwrap().as_str().unwrap(), "bcnn@1");
    let bcnn_logits = format!("{:?}", r.get("logits").unwrap());
    let r = a.roundtrip(&format!(r#"{{"op":"classify","model":"float","pixels":{img}}}"#));
    assert!(r.get("ok").unwrap().as_bool().unwrap(), "{r}");
    assert_eq!(r.get("model").unwrap().as_str().unwrap(), "float@1");
    assert_ne!(format!("{:?}", r.get("logits").unwrap()), bcnn_logits);

    // --- stream a 48-image group, swap versions while it's in flight ---
    const GROUP: usize = 48;
    let group = vec![img.clone(); GROUP].join(",");
    a.conn
        .write_all(
            format!(r#"{{"op":"classify_batch_stream","model":"","images":[{group}]}}"#)
                .as_bytes(),
        )
        .unwrap();
    a.conn.write_all(b"\n").unwrap();

    // admin lane: load bcnn@2 from disk and make it the default while
    // the stream above is being parsed/served
    let mut b = Client::connect(addr);
    let r = b.roundtrip(r#"{"op":"load_model","name":"bcnn","version":2}"#);
    assert!(r.get("ok").unwrap().as_bool().unwrap(), "{r}");
    assert_eq!(r.get("action").unwrap().as_str().unwrap(), "load_model");
    assert_eq!(r.get("model").unwrap().as_str().unwrap(), "bcnn@2");
    let r = b.roundtrip(r#"{"op":"set_default","name":"bcnn","version":2}"#);
    assert_eq!(r.get("action").unwrap().as_str().unwrap(), "set_default");
    assert_eq!(r.get("model").unwrap().as_str().unwrap(), "bcnn@2");

    // --- the stream completes: zero drops, zero failures, one version ---
    let mut ids = Vec::new();
    let mut versions = Vec::new();
    for _ in 0..GROUP {
        let frame = a.read_line();
        assert!(frame.get("stream").unwrap().as_bool().unwrap(), "{frame}");
        assert!(frame.get("ok").unwrap().as_bool().unwrap(), "no frame may fail: {frame}");
        ids.push(frame.get("id").unwrap().as_usize().unwrap());
        versions.push(frame.get("model").unwrap().as_str().unwrap().to_string());
    }
    let end = a.read_line();
    assert!(end.get("stream_end").unwrap().as_bool().unwrap(), "{end}");
    assert_eq!(end.get("count").unwrap().as_usize().unwrap(), GROUP);
    assert_eq!(end.get("completed").unwrap().as_usize().unwrap(), GROUP);
    assert_eq!(end.get("failed").unwrap().as_usize().unwrap(), 0);
    ids.sort_unstable();
    ids.dedup();
    assert_eq!(ids.len(), GROUP, "every image answered exactly once, real ids");
    // a group never mixes versions: whichever side of the swap the
    // request landed on, every frame reports the same entry
    versions.sort();
    versions.dedup();
    assert_eq!(versions.len(), 1, "one group, one version: {versions:?}");
    assert!(versions[0] == "bcnn@1" || versions[0] == "bcnn@2");

    // --- post-swap traffic on the SAME connection routes to v2 --------
    let r = a.roundtrip(&format!(r#"{{"op":"classify","pixels":{img}}}"#));
    assert_eq!(r.get("model").unwrap().as_str().unwrap(), "bcnn@2", "{r}");
    // pinned references still reach v1 until it is unloaded
    let r = a.roundtrip(&format!(r#"{{"op":"classify","model":"bcnn@1","pixels":{img}}}"#));
    assert_eq!(r.get("model").unwrap().as_str().unwrap(), "bcnn@1", "{r}");

    // --- retire v1; the registry reflects the whole lifecycle ----------
    let r = b.roundtrip(r#"{"op":"unload_model","name":"bcnn","version":1}"#);
    assert_eq!(r.get("action").unwrap().as_str().unwrap(), "unload_model");
    let r = a.roundtrip(&format!(r#"{{"op":"classify","model":"bcnn@1","pixels":{img}}}"#));
    assert!(!r.get("ok").unwrap().as_bool().unwrap(), "{r}");
    assert!(r.get("error").unwrap().as_str().unwrap().contains("unknown model"), "{r}");
    let r = a.roundtrip(&format!(r#"{{"op":"classify","model":"bcnn","pixels":{img}}}"#));
    assert_eq!(r.get("model").unwrap().as_str().unwrap(), "bcnn@2", "{r}");

    let r = b.roundtrip(r#"{"op":"list_models"}"#);
    let rows = r.get("models").unwrap().as_arr().unwrap();
    let keys: Vec<&str> =
        rows.iter().map(|row| row.get("model").unwrap().as_str().unwrap()).collect();
    assert_eq!(keys, vec!["bcnn@2", "float@1"]);
    for row in rows {
        assert!(row.get("serving").unwrap().as_bool().unwrap());
        assert!(row.get("checksum").unwrap().as_str().unwrap().starts_with("fnv1a64:"));
    }
    let counters = r.get("registry").unwrap();
    assert_eq!(counters.get("loads").unwrap().as_usize().unwrap(), 3);
    assert_eq!(counters.get("evictions").unwrap().as_usize().unwrap(), 1);
    assert!(counters.get("swaps").unwrap().as_usize().unwrap() >= 1);
    // per-model counters: the survivor served traffic
    let bcnn2 = rows.iter().find(|row| {
        row.get("model").unwrap().as_str().unwrap() == "bcnn@2"
    });
    assert!(bcnn2.unwrap().get("completed").unwrap().as_usize().unwrap() >= 1);

    stop.store(true, Ordering::Relaxed);
}

#[test]
fn repeated_swaps_under_continuous_streams_never_fail_a_request() {
    // a tighter hot-swap hammer: stream groups back to back while an
    // admin thread flips the default between two resident versions;
    // every group must complete fully on exactly one version
    let dir = write_models_dir("hammer");
    let (addr, stop) = start_server(&dir);
    {
        let mut admin = Client::connect(addr);
        let r = admin.roundtrip(r#"{"op":"load_model","name":"bcnn","version":2}"#);
        assert!(r.get("ok").unwrap().as_bool().unwrap(), "{r}");
    }

    let flipping = Arc::new(AtomicBool::new(true));
    let flipping2 = Arc::clone(&flipping);
    let admin = std::thread::spawn(move || {
        let mut admin = Client::connect(addr);
        let mut v = 2;
        while flipping2.load(Ordering::Relaxed) {
            let r = admin.roundtrip(&format!(
                r#"{{"op":"set_default","name":"bcnn","version":{v}}}"#
            ));
            assert!(r.get("ok").unwrap().as_bool().unwrap(), "{r}");
            v = if v == 2 { 1 } else { 2 };
            std::thread::sleep(Duration::from_millis(5));
        }
    });

    let mut client = Client::connect(addr);
    let img = one_image_json();
    const GROUP: usize = 16;
    let group = vec![img; GROUP].join(",");
    for _ in 0..6 {
        client
            .conn
            .write_all(
                format!(r#"{{"op":"classify_batch_stream","model":"","images":[{group}]}}"#)
                    .as_bytes(),
            )
            .unwrap();
        client.conn.write_all(b"\n").unwrap();
        let mut versions = Vec::new();
        for _ in 0..GROUP {
            let frame = client.read_line();
            assert!(frame.get("ok").unwrap().as_bool().unwrap(), "{frame}");
            versions.push(frame.get("model").unwrap().as_str().unwrap().to_string());
        }
        versions.sort();
        versions.dedup();
        assert_eq!(versions.len(), 1, "group mixed versions: {versions:?}");
        let end = client.read_line();
        assert_eq!(end.get("completed").unwrap().as_usize().unwrap(), GROUP, "{end}");
        assert_eq!(end.get("failed").unwrap().as_usize().unwrap(), 0, "{end}");
    }

    flipping.store(false, Ordering::Relaxed);
    admin.join().unwrap();
    stop.store(true, Ordering::Relaxed);
}

/// A topology the legacy fixed pipeline could never run: three packed
/// conv/pool stages (96 → 48 → 24 → 12 spatial) before the FC tail.
const DEEP_ARCH: &str = r#"[
    {"op": "binarize", "scheme": "gray"},
    {"op": "conv_bin", "k": 5, "out": 32},
    {"op": "threshold"},
    {"op": "orpool"},
    {"op": "conv_bin", "k": 3, "out": 32},
    {"op": "threshold"},
    {"op": "orpool"},
    {"op": "conv_bin", "k": 3, "out": 32},
    {"op": "threshold"},
    {"op": "orpool"},
    {"op": "fc_bin", "out": 64},
    {"op": "threshold"},
    {"op": "fc_float", "out": 4}
]"#;

#[test]
fn manifest_declared_arch_loads_smoke_infers_and_serves_end_to_end() {
    // THE acceptance test for the layer-graph tentpole: a registry
    // manifest carrying a non-default `arch` (3 convs) loads through the
    // background loader (checksum + plan compile + weight bind + smoke
    // inference), serves `classify_batch` over TCP, and answers logits
    // bit-identical to the compiled plan run in-process.
    let dir = std::env::temp_dir()
        .join(format!("bcnn-reg-arch-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let spec = NetworkSpec::from_json(&Json::parse(DEEP_ARCH).unwrap()).unwrap();
    let tf = synth_tf_for_spec(&spec, 9001);
    tf.save(dir.join("deep.bcnt")).unwrap();
    // the wire servers also need the legacy entries start_server loads
    synth_bcnn_tf(Scheme::Rgb, 9002).save(dir.join("bcnn_v1.bcnt")).unwrap();
    synth_float_tf(9003).save(dir.join("float_v1.bcnt")).unwrap();
    let sum = |f: &str| format_checksum(fnv1a64(&std::fs::read(dir.join(f)).unwrap()));
    let manifest = format!(
        r#"{{"version": 1, "default": "bcnn", "models": [
  {{"name": "bcnn", "version": 1, "kind": "bcnn", "scheme": "rgb",
    "weights_file": "bcnn_v1.bcnt", "checksum": "{}"}},
  {{"name": "float", "version": 1, "kind": "float", "scheme": "float",
    "weights_file": "float_v1.bcnt", "checksum": "{}"}},
  {{"name": "deep", "version": 1, "kind": "bcnn", "scheme": "gray",
    "weights_file": "deep.bcnt", "checksum": "{}",
    "batch": {{"max_images": 8, "executors": 2}},
    "arch": {DEEP_ARCH}}}
]}}"#,
        sum("bcnn_v1.bcnt"),
        sum("float_v1.bcnt"),
        sum("deep.bcnt"),
    );
    std::fs::write(dir.join("registry.json"), manifest).unwrap();

    let (addr, stop) = start_server(&dir);
    let mut c = Client::connect(addr);
    // hot-load the arch entry through the admin plane
    let r = c.roundtrip(r#"{"op":"load_model","name":"deep","version":1}"#);
    assert!(r.get("ok").unwrap().as_bool().unwrap(), "{r}");
    assert_eq!(r.get("model").unwrap().as_str().unwrap(), "deep@1");

    // classify_batch through the deep entry's own lane
    let img_a = vec!["0.5"; 96 * 96 * 3].join(",");
    let img_b = vec!["0.25"; 96 * 96 * 3].join(",");
    let r = c.roundtrip(&format!(
        r#"{{"op":"classify_batch","model":"deep","images":[[{img_a}],[{img_b}]]}}"#
    ));
    assert!(r.get("ok").unwrap().as_bool().unwrap(), "{r}");
    let results = r.get("results").unwrap().as_arr().unwrap();
    assert_eq!(results.len(), 2);

    // the wire logits match the compiled plan bit-for-bit (f32 → JSON
    // shortest-roundtrip f64 → f32 is lossless)
    let compiled = CompiledNetwork::from_tensor_file(&tf, &spec).unwrap();
    let mut payload = vec![0.5f32; 96 * 96 * 3];
    payload.extend(vec![0.25f32; 96 * 96 * 3]);
    let want = compiled.infer_batch(&payload).unwrap();
    for (i, row) in results.iter().enumerate() {
        assert!(row.get("ok").unwrap().as_bool().unwrap(), "{row}");
        assert_eq!(row.get("model").unwrap().as_str().unwrap(), "deep@1");
        let logits: Vec<f32> = row
            .get("logits")
            .unwrap()
            .as_arr()
            .unwrap()
            .iter()
            .map(|v| v.as_f64().unwrap() as f32)
            .collect();
        assert_eq!(logits, want[i].to_vec(), "image {i} drifted from the compiled plan");
    }

    // list_models reports the entry with its effective batch overrides
    let r = c.roundtrip(r#"{"op":"list_models"}"#);
    let rows = r.get("models").unwrap().as_arr().unwrap();
    let deep = rows
        .iter()
        .find(|row| row.get("model").unwrap().as_str().unwrap() == "deep@1")
        .expect("deep@1 listed");
    assert_eq!(deep.get("scheme").unwrap().as_str().unwrap(), "gray");
    let batch = deep.get("batch").unwrap();
    assert_eq!(batch.get("max_images").unwrap().as_usize().unwrap(), 8);
    assert_eq!(batch.get("executors").unwrap().as_usize().unwrap(), 2);
    stop.store(true, Ordering::Relaxed);
}

#[test]
fn a_plan_failing_verification_is_refused_over_the_wire() {
    // THE acceptance test for the verifier tentpole: a model whose
    // compiled plan is corrupted (via the loader's name-scoped fault
    // hook, standing in for a buggy future rewrite pass) must be
    // refused at load_model, counted in registry.verify_failures, and
    // never become resolvable — while healthy entries keep serving and
    // report their verification envelope in list_models.
    let dir = std::env::temp_dir()
        .join(format!("bcnn-reg-verify-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    synth_bcnn_tf(Scheme::Rgb, 7001).save(dir.join("bcnn_v1.bcnt")).unwrap();
    synth_float_tf(7002).save(dir.join("float_v1.bcnt")).unwrap();
    let sum = |f: &str| format_checksum(fnv1a64(&std::fs::read(dir.join(f)).unwrap()));
    let manifest = format!(
        r#"{{"version": 1, "default": "bcnn", "models": [
  {{"name": "bcnn", "version": 1, "kind": "bcnn", "scheme": "rgb",
    "weights_file": "bcnn_v1.bcnt", "checksum": "{}"}},
  {{"name": "float", "version": 1, "kind": "float", "scheme": "float",
    "weights_file": "float_v1.bcnt", "checksum": "{}"}},
  {{"name": "evil", "version": 1, "kind": "bcnn", "scheme": "rgb",
    "weights_file": "bcnn_v1.bcnt", "checksum": "{}"}}
]}}"#,
        sum("bcnn_v1.bcnt"),
        sum("float_v1.bcnt"),
        sum("bcnn_v1.bcnt"),
    );
    std::fs::write(dir.join("registry.json"), manifest).unwrap();

    let (addr, stop) = start_server(&dir);
    let mut c = Client::connect(addr);

    // corrupt "evil"'s plan between compilation and verification
    std::env::set_var("BCNN_TEST_CORRUPT_PLAN", "evil:writer-deletion");
    let r = c.roundtrip(r#"{"op":"load_model","name":"evil","version":1}"#);
    std::env::remove_var("BCNN_TEST_CORRUPT_PLAN");
    assert!(!r.get("ok").unwrap().as_bool().unwrap(), "{r}");
    let err = r.get("error").unwrap().as_str().unwrap();
    assert!(err.contains("verification failed"), "{r}");
    assert!(err.contains("evil@1"), "error must name the entry: {r}");

    // the refused entry never serves; healthy traffic is unaffected
    let img = one_image_json();
    let r = c.roundtrip(&format!(r#"{{"op":"classify","model":"evil","pixels":{img}}}"#));
    assert!(!r.get("ok").unwrap().as_bool().unwrap(), "{r}");
    let r = c.roundtrip(&format!(r#"{{"op":"classify","pixels":{img}}}"#));
    assert!(r.get("ok").unwrap().as_bool().unwrap(), "{r}");

    // the refusal is counted in its own registry counter
    let r = c.roundtrip(r#"{"op":"list_models"}"#);
    let counters = r.get("registry").unwrap();
    assert_eq!(counters.get("verify_failures").unwrap().as_usize().unwrap(), 1, "{r}");
    assert_eq!(counters.get("load_failures").unwrap().as_usize().unwrap(), 1, "{r}");
    // file-loaded entries carry their verification envelope
    let rows = r.get("models").unwrap().as_arr().unwrap();
    for row in rows {
        let report = row.get("verify").unwrap();
        assert!(report.get("steps").unwrap().as_usize().unwrap() > 0, "{row}");
        assert!(report.get("intervals").unwrap().as_usize().unwrap() > 0, "{row}");
    }

    // with the fault hook gone the same artifact verifies and publishes
    let r = c.roundtrip(r#"{"op":"load_model","name":"evil","version":1}"#);
    assert!(r.get("ok").unwrap().as_bool().unwrap(), "{r}");
    let r = c.roundtrip(&format!(r#"{{"op":"classify","model":"evil","pixels":{img}}}"#));
    assert!(r.get("ok").unwrap().as_bool().unwrap(), "{r}");
    stop.store(true, Ordering::Relaxed);
}

#[test]
fn admin_token_gates_the_wire_admin_plane() {
    let dir = write_models_dir("token");
    let (addr, stop) = start_server_with(&dir, Some("hunter2"));
    let mut c = Client::connect(addr);

    // no token / wrong token: refused, default untouched
    let r = c.roundtrip(r#"{"op":"load_model","name":"bcnn","version":2}"#);
    assert!(!r.get("ok").unwrap().as_bool().unwrap(), "{r}");
    assert!(r.get("error").unwrap().as_str().unwrap().contains("token"), "{r}");
    let r = c.roundtrip(r#"{"op":"set_default","name":"float","token":"wrong"}"#);
    assert!(!r.get("ok").unwrap().as_bool().unwrap(), "{r}");

    // classification and the read-only admin ops stay open
    let img = one_image_json();
    let r = c.roundtrip(&format!(r#"{{"op":"classify","pixels":{img}}}"#));
    assert_eq!(r.get("model").unwrap().as_str().unwrap(), "bcnn@1", "{r}");
    let r = c.roundtrip(r#"{"op":"list_models"}"#);
    assert!(r.get("ok").unwrap().as_bool().unwrap(), "{r}");

    // the right token drives the full lifecycle
    let r = c.roundtrip(r#"{"op":"load_model","name":"bcnn","version":2,"token":"hunter2"}"#);
    assert!(r.get("ok").unwrap().as_bool().unwrap(), "{r}");
    let r = c.roundtrip(r#"{"op":"set_default","name":"bcnn","version":2,"token":"hunter2"}"#);
    assert_eq!(r.get("model").unwrap().as_str().unwrap(), "bcnn@2", "{r}");
    let r = c.roundtrip(r#"{"op":"unload_model","name":"bcnn","version":1,"token":"hunter2"}"#);
    assert!(r.get("ok").unwrap().as_bool().unwrap(), "{r}");

    // rejections were counted for the operator
    let r = c.roundtrip(r#"{"op":"stats"}"#);
    let denied = r
        .get("stats")
        .unwrap()
        .get("server")
        .unwrap()
        .get("admin_denied")
        .unwrap()
        .as_usize()
        .unwrap();
    assert_eq!(denied, 2, "{r}");
    stop.store(true, Ordering::Relaxed);
}
