//! End-to-end serving integration: router + batcher + backends + TCP,
//! over real artifacts when present (engine-only parts run regardless).

use std::sync::Arc;

use bcnn::bnn::network::tests_support::{synth_bcnn_network, synth_image};
use bcnn::coordinator::{BatchPolicy, EngineBackend, InferBackend, Router};
use bcnn::input::binarize::Scheme;
use bcnn::registry::ModelRegistry;
use bcnn::runtime::Artifacts;
use bcnn::server::{Request, Response, Server};

fn engine_router(max_batch: usize) -> Arc<Router> {
    let rgb: Arc<dyn InferBackend> =
        Arc::new(EngineBackend::bcnn(synth_bcnn_network(Scheme::Rgb, 21), 2));
    let lbp: Arc<dyn InferBackend> =
        Arc::new(EngineBackend::bcnn(synth_bcnn_network(Scheme::Lbp, 22), 2));
    Arc::new(
        Router::builder()
            .policy(BatchPolicy {
                max_batch,
                max_wait: std::time::Duration::from_millis(1),
                ..BatchPolicy::default()
            })
            .queue_capacity(512)
            .variant("rgb", rgb)
            .variant("lbp", lbp)
            .build(),
    )
}

/// Registry with the same rgb + lbp engine entries the old fixed router
/// carried (bare names resolve to `…@1`).
fn engine_registry(max_batch: usize) -> Arc<ModelRegistry> {
    let registry = ModelRegistry::builder()
        .policy(BatchPolicy {
            max_batch,
            max_wait: std::time::Duration::from_millis(1),
            ..BatchPolicy::default()
        })
        .queue_capacity(512)
        .build();
    let rgb: Arc<dyn InferBackend> =
        Arc::new(EngineBackend::bcnn(synth_bcnn_network(Scheme::Rgb, 21), 2));
    let lbp: Arc<dyn InferBackend> =
        Arc::new(EngineBackend::bcnn(synth_bcnn_network(Scheme::Lbp, 22), 2));
    registry.publish_backend("rgb", 1, "bcnn", "rgb", None, rgb).unwrap();
    registry.publish_backend("lbp", 1, "bcnn", "lbp", None, lbp).unwrap();
    registry
}

fn classes() -> Vec<String> {
    vec!["bus".into(), "normal".into(), "truck".into(), "van".into()]
}

#[test]
fn multi_variant_routing_is_isolated() {
    let router = engine_router(1);
    let img = synth_image(1);
    let a = router.infer_blocking("rgb", img.clone()).unwrap();
    let b = router.infer_blocking("lbp", img).unwrap();
    assert!(a.error.is_none() && b.error.is_none());
    // different weights -> (almost surely) different logits
    assert_ne!(a.logits, b.logits);
}

#[test]
fn paper_protocol_1000_requests_single_sample() {
    // Section 2.2: 1000 images one at a time; mean per-sample time.
    let router = engine_router(1);
    let n = 1000;
    for i in 0..n {
        let resp = router.infer_blocking("rgb", synth_image(i as u64)).unwrap();
        assert!(resp.error.is_none());
        assert_eq!(resp.batch_size, 1);
    }
    let m = router.metrics("rgb").unwrap();
    assert_eq!(m.completed(), n as u64);
    let snap = m.snapshot();
    let mean_us = snap.get("e2e_us").unwrap().get("mean").unwrap().as_f64().unwrap();
    assert!(mean_us > 0.0);
    println!("paper-protocol mean e2e = {mean_us:.1} µs over {n} samples");
}

#[test]
fn batching_aggregates_under_load() {
    let router = engine_router(16);
    let mut rxs = Vec::new();
    for i in 0..64 {
        let (_, rx) = router.submit("rgb", synth_image(i)).unwrap();
        rxs.push(rx);
    }
    for rx in rxs {
        let resp = rx.recv().unwrap();
        assert!(resp.error.is_none());
    }
    let snap = router.metrics("rgb").unwrap().snapshot();
    let mean_batch = snap.get("mean_batch_size").unwrap().as_f64().unwrap();
    assert!(mean_batch > 1.0, "batching never engaged: mean={mean_batch}");
}

#[test]
fn server_in_process_roundtrip() {
    let server = Server::new(engine_registry(1), classes());
    match server.handle(Request::ClassifySynth { model: "rgb".into(), index: 0 }) {
        Response::Classified { model, label, .. } => {
            assert_eq!(model, "rgb@1", "the response names the serving entry");
            assert!(["bus", "normal", "truck", "van"].contains(&label.as_str()))
        }
        other => panic!("{other:?}"),
    }
    match server.handle(Request::Stats) {
        Response::Stats(s) => {
            assert!(s.get("lanes").unwrap().get("rgb@1").is_ok());
            assert!(s.get("registry").is_ok() && s.get("server").is_ok());
        }
        other => panic!("{other:?}"),
    }
}

/// A backend that fails every Nth batch — exercises error fan-out.
struct FlakyBackend {
    fail_every: u64,
    calls: std::sync::atomic::AtomicU64,
}

impl InferBackend for FlakyBackend {
    fn name(&self) -> String {
        "flaky".into()
    }

    fn supported_batches(&self) -> Vec<usize> {
        vec![usize::MAX]
    }

    fn infer_batch(&self, images: &[f32]) -> Result<Vec<f32>, String> {
        let c = self.calls.fetch_add(1, std::sync::atomic::Ordering::SeqCst);
        if (c + 1) % self.fail_every == 0 {
            return Err("injected failure".into());
        }
        let n = images.len() / (96 * 96 * 3);
        Ok(vec![0.25f32; n * 4])
    }
}

#[test]
fn backend_failures_propagate_to_clients() {
    let be: Arc<dyn InferBackend> =
        Arc::new(FlakyBackend { fail_every: 3, calls: Default::default() });
    let router = Router::builder()
        .policy(BatchPolicy {
            max_batch: 1,
            max_wait: std::time::Duration::from_micros(50),
            ..BatchPolicy::default()
        })
        .variant("flaky", be)
        .build();
    let mut failures = 0;
    for i in 0..9u64 {
        let resp = router.infer_blocking("flaky", synth_image(i)).unwrap();
        if let Some(msg) = resp.error {
            assert!(msg.contains("injected"));
            failures += 1;
        } else {
            assert_eq!(resp.logits, vec![0.25; 4]);
        }
    }
    assert_eq!(failures, 3, "every third batch fails");
    router.shutdown();
}

#[test]
fn queue_overflow_rejects_cleanly() {
    // a slow backend + tiny queue forces admission rejections
    struct Slow;
    impl InferBackend for Slow {
        fn name(&self) -> String {
            "slow".into()
        }
        fn supported_batches(&self) -> Vec<usize> {
            vec![usize::MAX]
        }
        fn infer_batch(&self, images: &[f32]) -> Result<Vec<f32>, String> {
            std::thread::sleep(std::time::Duration::from_millis(30));
            Ok(vec![0.0; images.len() / (96 * 96 * 3) * 4])
        }
    }
    let router = Router::builder()
        .policy(BatchPolicy {
            max_batch: 1,
            max_wait: std::time::Duration::from_micros(10),
            ..BatchPolicy::default()
        })
        .queue_capacity(2)
        .variant("slow", Arc::new(Slow))
        .build();
    let mut rejected = 0;
    let mut rxs = Vec::new();
    for i in 0..12u64 {
        match router.submit("slow", synth_image(i)) {
            Ok((_, rx)) => rxs.push(rx),
            Err(e) => {
                assert!(e.to_string().contains("backpressure"), "{e}");
                rejected += 1;
            }
        }
    }
    assert!(rejected > 0, "tiny queue must reject under burst");
    for rx in rxs {
        assert!(rx.recv().unwrap().error.is_none());
    }
    router.shutdown();
}

#[test]
fn batched_submission_rides_one_backend_batch_and_matches_singles() {
    // the tentpole e2e: images submitted back-to-back coalesce into
    // batched backend calls, and logits stay bit-identical to the
    // single-image protocol
    let router = engine_router(16);
    let net = synth_bcnn_network(Scheme::Rgb, 21); // same weights as the router's rgb lane
    let images: Vec<Vec<f32>> = (0..16u64).map(synth_image).collect();
    let resps = router.infer_blocking_batch("rgb", images.clone());
    assert_eq!(resps.len(), 16);
    for (i, resp) in resps.iter().enumerate() {
        assert!(resp.error.is_none());
        let (want, _) = net.forward(&images[i]);
        assert_eq!(resp.logits, want.to_vec(), "image {i} logits drifted from single path");
    }
    let snap = router.metrics("rgb").unwrap().snapshot();
    let mean_batch = snap.get("mean_batch_size").unwrap().as_f64().unwrap();
    assert!(mean_batch > 1.0, "batch submission never batched: mean={mean_batch}");
}

#[test]
fn tcp_survives_garbage_bytes_and_answers_structured_errors() {
    use std::io::{BufRead, BufReader, Write};
    use std::net::TcpStream;
    use std::sync::atomic::{AtomicBool, Ordering};

    let server = Arc::new(Server::new(engine_registry(4), classes()));
    let stop = Arc::new(AtomicBool::new(false));
    let addr = Arc::clone(&server).serve("127.0.0.1:0", 2, Arc::clone(&stop)).unwrap();

    let mut conn = TcpStream::connect(addr).unwrap();
    let mut reader = BufReader::new(conn.try_clone().unwrap());
    let mut line = String::new();

    // 1. binary garbage (invalid UTF-8, not JSON)
    conn.write_all(b"\xff\xfe\x00\x01garbage\n").unwrap();
    reader.read_line(&mut line).unwrap();
    assert!(line.contains("\"ok\": false") || line.contains("\"ok\":false"), "{line}");

    // 2. truncated JSON
    line.clear();
    conn.write_all(b"{\"op\":\"classify\",\"pixels\":[1.0,\n").unwrap();
    reader.read_line(&mut line).unwrap();
    assert!(line.contains("error"), "{line}");

    // 3. valid op with a wrong-sized pixel payload: the engine must
    //    answer with a protocol error, not abort a worker on an assert
    line.clear();
    conn.write_all(b"{\"op\":\"classify\",\"model\":\"rgb\",\"pixels\":[0.5,0.5]}\n").unwrap();
    reader.read_line(&mut line).unwrap();
    assert!(line.contains("error"), "{line}");

    // 3b. a deeply nested JSON bomb (stack-overflow attempt) must come
    //     back as a parse error, not kill the server process
    line.clear();
    let mut bomb = "[".repeat(50_000);
    bomb.push('\n');
    conn.write_all(bomb.as_bytes()).unwrap();
    reader.read_line(&mut line).unwrap();
    assert!(line.contains("error"), "{line}");

    // 4. the session is still alive: a valid request succeeds on the SAME
    //    connection after all that garbage
    line.clear();
    conn.write_all(b"{\"op\":\"classify_synth\",\"model\":\"rgb\",\"index\":2}\n").unwrap();
    reader.read_line(&mut line).unwrap();
    assert!(line.contains("label"), "{line}");

    // 5. batched classify over the wire
    line.clear();
    let px: Vec<String> = vec!["0.5".to_string(); 96 * 96 * 3];
    let img = format!("[{}]", px.join(","));
    let req = format!("{{\"op\":\"classify_batch\",\"model\":\"rgb\",\"images\":[{img},{img}]}}\n");
    conn.write_all(req.as_bytes()).unwrap();
    reader.read_line(&mut line).unwrap();
    assert!(line.contains("results"), "{line}");
    assert!(line.contains("label"), "{line}");

    stop.store(true, Ordering::Relaxed);
}

#[test]
fn non_finite_pixels_rejected_end_to_end() {
    use std::io::{BufRead, BufReader, Write};
    use std::net::TcpStream;
    use std::sync::atomic::{AtomicBool, Ordering};

    let server = Arc::new(Server::new(engine_registry(4), classes()));
    let stop = Arc::new(AtomicBool::new(false));
    let addr = Arc::clone(&server).serve("127.0.0.1:0", 2, Arc::clone(&stop)).unwrap();

    let mut conn = TcpStream::connect(addr).unwrap();
    let mut reader = BufReader::new(conn.try_clone().unwrap());
    let mut line = String::new();

    // regression: a full-size payload with one 1e400 pixel used to parse
    // as f64::INFINITY, cast to f32 inf, produce NaN logits, and argmax
    // silently answered class 0 ("bus").  Must be a structured error.
    let mut px: Vec<String> = vec!["0.5".to_string(); 96 * 96 * 3];
    px[7] = "1e400".to_string();
    let req = format!("{{\"op\":\"classify\",\"model\":\"rgb\",\"pixels\":[{}]}}\n", px.join(","));
    conn.write_all(req.as_bytes()).unwrap();
    reader.read_line(&mut line).unwrap();
    assert!(line.contains("\"ok\": false") || line.contains("\"ok\":false"), "{line}");
    assert!(line.contains("non-finite"), "{line}");
    assert!(!line.contains("bus"), "NaN logits must not classify: {line}");

    // same guard on the batch op: the poisoned request fails as a whole
    // at the protocol layer (no image reaches the network)
    line.clear();
    let bad_img = format!("[{}]", px.join(","));
    let req = format!("{{\"op\":\"classify_batch\",\"model\":\"rgb\",\"images\":[{bad_img}]}}\n");
    conn.write_all(req.as_bytes()).unwrap();
    reader.read_line(&mut line).unwrap();
    assert!(line.contains("non-finite"), "{line}");

    // the session survives and still answers honest requests
    line.clear();
    conn.write_all(b"{\"op\":\"classify_synth\",\"model\":\"rgb\",\"index\":1}\n").unwrap();
    reader.read_line(&mut line).unwrap();
    assert!(line.contains("label"), "{line}");

    stop.store(true, Ordering::Relaxed);
}

#[test]
fn non_finite_logits_fail_per_image_in_batcher() {
    // Defense in depth behind the protocol check: a backend that emits
    // NaN logits (bad artifact, runtime bug) must produce a per-image
    // error, never a silent class-0 answer.
    struct NanBackend;
    impl InferBackend for NanBackend {
        fn name(&self) -> String {
            "nan".into()
        }
        fn supported_batches(&self) -> Vec<usize> {
            vec![usize::MAX]
        }
        fn infer_batch(&self, images: &[f32]) -> Result<Vec<f32>, String> {
            Ok(vec![f32::NAN; images.len() / (96 * 96 * 3) * 4])
        }
    }
    let router = Router::builder().variant("nan", Arc::new(NanBackend)).build();
    let resp = router.infer_blocking("nan", synth_image(1)).unwrap();
    let err = resp.error.expect("NaN logits must surface as an error");
    assert!(err.contains("non-finite"), "{err}");
    // the incident shows up in the stats op as a failure, not a completion
    let snap = router.metrics("nan").unwrap().snapshot();
    assert_eq!(snap.get("failed").unwrap().as_usize().unwrap(), 1, "{snap}");
    assert_eq!(snap.get("completed").unwrap().as_usize().unwrap(), 0, "{snap}");
    router.shutdown();
}

/// A backend whose per-batch latency is controlled by the first pixel:
/// images with pixel0 > 0.5 sleep `slow_ms` before answering.  Logits
/// echo pixel0 so responses can be traced back to their requests.
struct SleepyBackend {
    slow_ms: u64,
}

impl InferBackend for SleepyBackend {
    fn name(&self) -> String {
        "sleepy".into()
    }
    fn supported_batches(&self) -> Vec<usize> {
        vec![usize::MAX]
    }
    fn infer_batch(&self, images: &[f32]) -> Result<Vec<f32>, String> {
        const IMG: usize = 96 * 96 * 3;
        let n = images.len() / IMG;
        if (0..n).any(|i| images[i * IMG] > 0.5) {
            std::thread::sleep(std::time::Duration::from_millis(self.slow_ms));
        }
        let mut out = vec![0.0f32; n * 4];
        for i in 0..n {
            out[i * 4] = images[i * IMG];
        }
        Ok(out)
    }
}

#[test]
fn stream_delivers_fast_image_before_slow_peer_completes() {
    // the tentpole acceptance test: with a multi-executor lane, a fast
    // image's streamed response arrives while a slow image in the SAME
    // request group is still executing
    use std::io::{BufRead, BufReader, Write};
    use std::net::TcpStream;
    use std::sync::atomic::{AtomicBool, Ordering};

    // generous sleep/budget gap: the budget must absorb server-side parse
    // of a ~1.4 MB request in a debug build on a loaded CI host without
    // flaking (the load-bearing assertion is the frame ORDER, the timing
    // bound is belt-and-braces)
    const SLOW_MS: u64 = 1500;
    let be: Arc<dyn InferBackend> = Arc::new(SleepyBackend { slow_ms: SLOW_MS });
    let registry = ModelRegistry::builder()
        .policy(BatchPolicy {
            max_batch: 1, // each image is its own batch...
            max_wait: std::time::Duration::from_micros(10),
            executors: 2, // ...and two executors run them concurrently
        })
        .build();
    registry.publish_backend("sleepy", 1, "custom", "rgb", None, be).unwrap();
    let server = Arc::new(Server::new(registry, classes()));
    let stop = Arc::new(AtomicBool::new(false));
    let addr = Arc::clone(&server).serve("127.0.0.1:0", 2, Arc::clone(&stop)).unwrap();

    let mut conn = TcpStream::connect(addr).unwrap();
    let mut reader = BufReader::new(conn.try_clone().unwrap());
    // seq 0 is the SLOW image (pixel0=0.9), seq 1 the fast one (0.1)
    let slow = "0.9,".to_string() + &vec!["0.0"; 96 * 96 * 3 - 1].join(",");
    let fast = "0.1,".to_string() + &vec!["0.0"; 96 * 96 * 3 - 1].join(",");
    let req = format!(
        "{{\"op\":\"classify_batch_stream\",\"model\":\"sleepy\",\"images\":[[{slow}],[{fast}]]}}\n"
    );
    conn.write_all(req.as_bytes()).unwrap();

    let started = std::time::Instant::now();
    let mut line = String::new();
    reader.read_line(&mut line).unwrap();
    let first_frame_after = started.elapsed();
    // the FIRST frame on the wire is the fast image (submitted second),
    // and it arrives before the slow image's SLOW_MS sleep can finish
    let first = bcnn::util::json::Json::parse(&line).unwrap();
    assert!(first.get("stream").unwrap().as_bool().unwrap(), "{line}");
    assert_eq!(first.get("seq").unwrap().as_usize().unwrap(), 1, "fast image first: {line}");
    assert!(
        first_frame_after < std::time::Duration::from_millis(SLOW_MS - 100),
        "fast frame waited on the slow batch: {first_frame_after:?}"
    );
    line.clear();
    reader.read_line(&mut line).unwrap();
    let second = bcnn::util::json::Json::parse(&line).unwrap();
    assert_eq!(second.get("seq").unwrap().as_usize().unwrap(), 0, "{line}");
    line.clear();
    reader.read_line(&mut line).unwrap();
    let end = bcnn::util::json::Json::parse(&line).unwrap();
    assert!(end.get("stream_end").unwrap().as_bool().unwrap(), "{line}");
    assert_eq!(end.get("completed").unwrap().as_usize().unwrap(), 2, "{line}");
    stop.store(true, Ordering::Relaxed);
}

#[test]
fn multi_executor_lane_is_bit_identical_to_serial_lane() {
    // acceptance: N>=2 executors produce bit-identical logits to the
    // serial lane for the same request set
    let images: Vec<Vec<f32>> = (0..24u64).map(synth_image).collect();
    let mut runs: Vec<Vec<Vec<f32>>> = Vec::new();
    for executors in [1usize, 4] {
        let be: Arc<dyn InferBackend> =
            Arc::new(EngineBackend::bcnn(synth_bcnn_network(Scheme::Rgb, 33), 2));
        let router = Router::builder()
            .policy(BatchPolicy {
                max_batch: 8,
                max_wait: std::time::Duration::from_millis(1),
                executors,
            })
            .variant("rgb", be)
            .build();
        let resps = router.infer_blocking_batch("rgb", images.clone());
        assert_eq!(resps.len(), images.len());
        runs.push(
            resps
                .into_iter()
                .map(|resp| {
                    assert!(resp.error.is_none(), "{:?}", resp.error);
                    resp.logits
                })
                .collect(),
        );
        router.shutdown();
    }
    assert_eq!(runs[0], runs[1], "executors=4 drifted from the serial lane");
}

#[test]
fn stream_failure_frames_mix_parse_rejects_and_nan_logits() {
    // satellite: a group mixing valid images, a non-finite-pixel reject,
    // and a NaN-logit backend must stream per-image failure frames with
    // real request ids and still deliver the terminal summary
    use std::io::{BufRead, BufReader, Write};
    use std::net::TcpStream;
    use std::sync::atomic::{AtomicBool, Ordering};

    /// Finite on the first call — so it passes the registry's smoke
    /// gate — then degrades to NaN logits: the runtime-failure shape
    /// the batcher's defense-in-depth exists for.
    struct LatentNanBackend {
        calls: std::sync::atomic::AtomicU64,
    }
    impl InferBackend for LatentNanBackend {
        fn name(&self) -> String {
            "latent-nan".into()
        }
        fn supported_batches(&self) -> Vec<usize> {
            vec![usize::MAX]
        }
        fn infer_batch(&self, images: &[f32]) -> Result<Vec<f32>, String> {
            let c = self.calls.fetch_add(1, std::sync::atomic::Ordering::SeqCst);
            let n = images.len() / (96 * 96 * 3);
            if c == 0 {
                return Ok(vec![0.25; n * 4]);
            }
            Ok(vec![f32::NAN; n * 4])
        }
    }
    let registry = ModelRegistry::builder().build();
    registry
        .publish_backend(
            "nan",
            1,
            "custom",
            "rgb",
            None,
            Arc::new(LatentNanBackend { calls: Default::default() }),
        )
        .unwrap();
    let server = Arc::new(Server::new(registry, classes()));
    let stop = Arc::new(AtomicBool::new(false));
    let addr = Arc::clone(&server).serve("127.0.0.1:0", 2, Arc::clone(&stop)).unwrap();

    let mut conn = TcpStream::connect(addr).unwrap();
    let mut reader = BufReader::new(conn.try_clone().unwrap());
    let good = vec!["0.5"; 96 * 96 * 3].join(",");
    let mut poisoned: Vec<&str> = vec!["0.5"; 96 * 96 * 3];
    poisoned[7] = "1e400"; // non-finite at parse time
    let poisoned = poisoned.join(",");
    let req = format!(
        "{{\"op\":\"classify_batch_stream\",\"model\":\"nan\",\
         \"images\":[[{good}],[{poisoned}],[{good}]]}}\n"
    );
    conn.write_all(req.as_bytes()).unwrap();

    let mut ids = Vec::new();
    let mut seqs = Vec::new();
    for _ in 0..3 {
        let mut line = String::new();
        reader.read_line(&mut line).unwrap();
        let j = bcnn::util::json::Json::parse(&line).unwrap();
        assert!(j.get("stream").unwrap().as_bool().unwrap(), "{line}");
        assert!(!j.get("ok").unwrap().as_bool().unwrap(), "every image fails: {line}");
        let err = j.get("error").unwrap().as_str().unwrap().to_string();
        assert!(err.contains("non-finite"), "{line}");
        ids.push(j.get("id").unwrap().as_usize().unwrap());
        seqs.push(j.get("seq").unwrap().as_usize().unwrap());
    }
    // real, distinct ids on every failure frame; all seqs accounted for
    ids.sort_unstable();
    ids.dedup();
    assert_eq!(ids.len(), 3);
    assert!(ids.iter().all(|&id| id != 0));
    seqs.sort_unstable();
    assert_eq!(seqs, vec![0, 1, 2]);
    // the terminal summary still arrives, naming every image
    let mut line = String::new();
    reader.read_line(&mut line).unwrap();
    let end = bcnn::util::json::Json::parse(&line).unwrap();
    assert!(end.get("stream_end").unwrap().as_bool().unwrap(), "{line}");
    assert_eq!(end.get("count").unwrap().as_usize().unwrap(), 3, "{line}");
    assert_eq!(end.get("failed").unwrap().as_usize().unwrap(), 3, "{line}");
    assert_eq!(end.get("results").unwrap().as_arr().unwrap().len(), 3, "{line}");
    stop.store(true, Ordering::Relaxed);
}

#[test]
fn stalled_client_is_disconnected_by_the_write_deadline() {
    // satellite: a client that stops reading must not pin a session-pool
    // thread forever.  One connection floods pings and never reads —
    // once the TCP windows fill, the server's blocking write trips the
    // per-session deadline, the session is disconnected, and the
    // incident is counted in the stats op (observed from a second,
    // healthy connection).
    use std::io::{BufRead, BufReader, Write};
    use std::net::TcpStream;
    use std::sync::atomic::{AtomicBool, Ordering};

    let server = Arc::new(
        Server::new(engine_registry(1), classes())
            .with_write_timeout(Some(std::time::Duration::from_millis(200))),
    );
    let stop = Arc::new(AtomicBool::new(false));
    let addr = Arc::clone(&server).serve("127.0.0.1:0", 2, Arc::clone(&stop)).unwrap();

    // the stalled client: pipeline pings from a writer thread, read
    // nothing.  Its own write timeout bounds every syscall so the
    // thread can always be joined, even if the server misbehaves.
    let stalled = TcpStream::connect(addr).unwrap();
    stalled.set_write_timeout(Some(std::time::Duration::from_millis(500))).unwrap();
    let done = Arc::new(AtomicBool::new(false));
    let done2 = Arc::clone(&done);
    let mut stalled_writer = stalled.try_clone().unwrap();
    let writer = std::thread::spawn(move || {
        let chunk = "{\"op\":\"ping\"}\n".repeat(512);
        while !done2.load(Ordering::Relaxed) {
            // once both directions are full (the server has stalled on
            // its response write), our writes error out — job done: the
            // server now has a deep backlog of answered-but-unread data
            if stalled_writer.write_all(chunk.as_bytes()).is_err() {
                break;
            }
        }
    });

    // a healthy observer polls the stats op for the recorded timeout
    let mut conn = TcpStream::connect(addr).unwrap();
    let mut reader = BufReader::new(conn.try_clone().unwrap());
    let deadline = std::time::Instant::now() + std::time::Duration::from_secs(30);
    let mut saw_timeout = false;
    while std::time::Instant::now() < deadline {
        conn.write_all(b"{\"op\":\"stats\"}\n").unwrap();
        let mut line = String::new();
        reader.read_line(&mut line).unwrap();
        let j = bcnn::util::json::Json::parse(&line).unwrap();
        let n = j
            .get("stats")
            .unwrap()
            .get("server")
            .unwrap()
            .get("write_timeouts")
            .unwrap()
            .as_usize()
            .unwrap();
        if n >= 1 {
            saw_timeout = true;
            break;
        }
        std::thread::sleep(std::time::Duration::from_millis(50));
    }
    done.store(true, Ordering::Relaxed);
    drop(stalled); // unblock the writer thread if it's wedged in write()
    let _ = writer.join();
    assert!(saw_timeout, "stalled client never tripped the write deadline");
    stop.store(true, Ordering::Relaxed);
}

#[test]
fn stream_end_summary_names_the_serving_model() {
    // regression (ISSUE 5 satellite): PR 4 added the serving
    // `name@version` to per-image `Classified` frames only — the
    // terminal `stream_end` summary must carry it too
    use std::io::{BufRead, BufReader, Write};
    use std::net::TcpStream;
    use std::sync::atomic::{AtomicBool, Ordering};

    let server = Arc::new(Server::new(engine_registry(4), classes()));
    let stop = Arc::new(AtomicBool::new(false));
    let addr = Arc::clone(&server).serve("127.0.0.1:0", 2, Arc::clone(&stop)).unwrap();

    let mut conn = TcpStream::connect(addr).unwrap();
    let mut reader = BufReader::new(conn.try_clone().unwrap());
    let img = vec!["0.5"; 96 * 96 * 3].join(",");
    let req =
        format!("{{\"op\":\"classify_batch_stream\",\"model\":\"lbp\",\"images\":[[{img}],[{img}]]}}\n");
    conn.write_all(req.as_bytes()).unwrap();
    let mut line = String::new();
    for _ in 0..2 {
        line.clear();
        reader.read_line(&mut line).unwrap();
        let frame = bcnn::util::json::Json::parse(&line).unwrap();
        assert_eq!(frame.get("model").unwrap().as_str().unwrap(), "lbp@1", "{line}");
    }
    line.clear();
    reader.read_line(&mut line).unwrap();
    let end = bcnn::util::json::Json::parse(&line).unwrap();
    assert!(end.get("stream_end").unwrap().as_bool().unwrap(), "{line}");
    assert_eq!(
        end.get("model").unwrap().as_str().unwrap(),
        "lbp@1",
        "stream_end must name the serving entry like per-image frames: {line}"
    );

    // an unresolvable reference streams per-image failures and an EMPTY
    // model in the summary (nothing served the group)
    let req = format!(
        "{{\"op\":\"classify_batch_stream\",\"model\":\"ghost\",\"images\":[[{img}]]}}\n"
    );
    conn.write_all(req.as_bytes()).unwrap();
    line.clear();
    reader.read_line(&mut line).unwrap(); // the per-image failure frame
    line.clear();
    reader.read_line(&mut line).unwrap();
    let end = bcnn::util::json::Json::parse(&line).unwrap();
    assert!(end.get("stream_end").unwrap().as_bool().unwrap(), "{line}");
    assert_eq!(end.get("model").unwrap().as_str().unwrap(), "", "{line}");
    stop.store(true, Ordering::Relaxed);
}

/// Render an image as a JSON pixel array.  `f32` Display emits the
/// shortest decimal that round-trips, so the server's parse (f64, then
/// cast) recovers the exact same f32 bits — the wire adds no error.
fn json_image(img: &[f32]) -> String {
    let px: Vec<String> = img.iter().map(|v| format!("{v}")).collect();
    format!("[{}]", px.join(","))
}

#[test]
fn manifest_declared_residual_arch_serves_bit_equal_over_tcp() {
    // acceptance (ISSUE 8 tentpole): a registry.json entry declaring a
    // binary-residual block — the conv's popcount-counts edge read by
    // BOTH the threshold chain and the Add skip, with an XNOR-Net
    // `scale` bridging the sum back into floats — must load through the
    // full gauntlet (checksum, verify, equiv-checked rewrite, smoke)
    // and serve classify_batch over a real socket bit-identical to the
    // same graph executed in process.
    use std::io::{BufRead, BufReader, Write};
    use std::net::TcpStream;
    use std::sync::atomic::{AtomicBool, Ordering};

    use bcnn::bnn::graph::{CompiledNetwork, NetworkSpec};
    use bcnn::bnn::network::tests_support::synth_tf_for_spec;
    use bcnn::registry::{fnv1a64, format_checksum};
    use bcnn::util::json::Json;

    const ARCH: &str = r#"[
        {"op": "binarize", "scheme": "rgb"},
        {"op": "conv_bin", "k": 5, "out": 32},
        {"op": "threshold"},
        {"op": "conv_bin", "k": 1, "out": 32},
        {"op": "add", "with": 1},
        {"op": "scale"},
        {"op": "maxpool"},
        {"op": "fc_float", "out": 4}
    ]"#;
    let spec = NetworkSpec::from_json(&Json::parse(ARCH).unwrap()).unwrap();
    let tf = synth_tf_for_spec(&spec, 808);
    let dir = std::env::temp_dir().join(format!("bcnn-resid-tcp-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    tf.save(dir.join("resid.bcnt")).unwrap();
    let sum = format_checksum(fnv1a64(&std::fs::read(dir.join("resid.bcnt")).unwrap()));
    let manifest = format!(
        r#"{{"models": [
  {{"name": "resid", "version": 1, "kind": "bcnn", "scheme": "rgb",
    "weights_file": "resid.bcnt", "checksum": "{sum}",
    "arch": {ARCH}}}
]}}"#
    );
    std::fs::write(dir.join("registry.json"), manifest).unwrap();

    let registry = ModelRegistry::builder()
        .queue_capacity(64)
        .engine_threads(1)
        .models_dir(&dir)
        .build();
    let server = Arc::new(Server::new(registry, classes()));
    let stop = Arc::new(AtomicBool::new(false));
    let addr = Arc::clone(&server).serve("127.0.0.1:0", 2, Arc::clone(&stop)).unwrap();
    let mut conn = TcpStream::connect(addr).unwrap();
    let mut reader = BufReader::new(conn.try_clone().unwrap());
    let mut line = String::new();
    conn.write_all(b"{\"op\":\"load_model\",\"name\":\"resid\",\"version\":1}\n").unwrap();
    reader.read_line(&mut line).unwrap();
    assert!(line.contains("load_model") && line.contains("resid@1"), "{line}");

    // the in-process reference: the same spec + weights, compiled and
    // run directly (the served rewritten plan must agree bit-for-bit)
    let reference = CompiledNetwork::from_plan(spec.plan().unwrap(), &tf).unwrap();
    let images: Vec<Vec<f32>> = (0..3u64).map(synth_image).collect();
    let flat: Vec<f32> = images.iter().flatten().copied().collect();
    let want = reference.infer_batch(&flat).unwrap();
    assert_eq!(want.len(), 3 * 4);

    let body: Vec<String> = images.iter().map(|img| json_image(img)).collect();
    let req = format!(
        "{{\"op\":\"classify_batch\",\"model\":\"resid@1\",\"images\":[{}]}}\n",
        body.join(",")
    );
    conn.write_all(req.as_bytes()).unwrap();
    line.clear();
    reader.read_line(&mut line).unwrap();
    let j = Json::parse(&line).unwrap();
    let results = j.get("results").unwrap().as_arr().unwrap();
    assert_eq!(results.len(), 3);
    for (i, r) in results.iter().enumerate() {
        assert_eq!(r.get("model").unwrap().as_str().unwrap(), "resid@1", "{line}");
        let logits: Vec<f32> = r
            .get("logits")
            .unwrap()
            .as_arr()
            .unwrap()
            .iter()
            .map(|v| v.as_f64().unwrap() as f32)
            .collect();
        let want_row = &want[i * 4..(i + 1) * 4];
        assert_eq!(
            logits.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
            want_row.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
            "image {i}: TCP logits drifted from the in-process plan"
        );
    }
    // the proof envelope for the served (branch) plan is operator-visible
    line.clear();
    conn.write_all(b"{\"op\":\"list_models\"}\n").unwrap();
    reader.read_line(&mut line).unwrap();
    let j = Json::parse(&line).unwrap();
    let rows = j.get("models").unwrap().as_arr().unwrap();
    assert_eq!(rows.len(), 1);
    let verify = rows[0].get("verify").unwrap();
    assert!(verify.get("steps").unwrap().as_usize().unwrap() > 0, "{line}");
    assert!(verify.get("intervals").unwrap().as_usize().unwrap() > 0, "{line}");
    stop.store(true, Ordering::Relaxed);
}

#[test]
fn six_class_head_round_trips_its_logit_count_over_tcp() {
    // acceptance (ISSUE 8): logit width is the PLAN's declaration, not
    // the legacy NUM_CLASSES pin — a six-class split/scale/concat head
    // must answer exactly six logits end to end over the wire, bit-equal
    // to the in-process graph, with argmax/labels degrading gracefully
    // for classes beyond the server's four label strings.
    use std::io::{BufRead, BufReader, Write};
    use std::net::TcpStream;
    use std::sync::atomic::{AtomicBool, Ordering};

    use bcnn::bnn::graph::{CompiledNetwork, NetworkSpec};
    use bcnn::bnn::network::tests_support::synth_tf_for_spec;
    use bcnn::registry::{fnv1a64, format_checksum};
    use bcnn::util::json::Json;

    const ARCH: &str = r#"[
        {"op": "conv_float", "k": 5, "out": 8, "relu": true},
        {"op": "split", "parts": [3, 5]},
        {"op": "scale"},
        {"op": "concat", "with": [1, 1]},
        {"op": "maxpool"},
        {"op": "fc_float", "out": 6}
    ]"#;
    let spec = NetworkSpec::from_json(&Json::parse(ARCH).unwrap()).unwrap();
    let tf = synth_tf_for_spec(&spec, 606);
    let dir = std::env::temp_dir().join(format!("bcnn-wide-tcp-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    tf.save(dir.join("wide.bcnt")).unwrap();
    let sum = format_checksum(fnv1a64(&std::fs::read(dir.join("wide.bcnt")).unwrap()));
    let manifest = format!(
        r#"{{"models": [
  {{"name": "wide", "version": 1, "kind": "float", "scheme": "none",
    "weights_file": "wide.bcnt", "checksum": "{sum}",
    "arch": {ARCH}}}
]}}"#
    );
    std::fs::write(dir.join("registry.json"), manifest).unwrap();

    let registry = ModelRegistry::builder()
        .queue_capacity(64)
        .engine_threads(1)
        .models_dir(&dir)
        .build();
    let server = Arc::new(Server::new(registry, classes()));
    let stop = Arc::new(AtomicBool::new(false));
    let addr = Arc::clone(&server).serve("127.0.0.1:0", 2, Arc::clone(&stop)).unwrap();
    let mut conn = TcpStream::connect(addr).unwrap();
    let mut reader = BufReader::new(conn.try_clone().unwrap());
    let mut line = String::new();
    conn.write_all(b"{\"op\":\"load_model\",\"name\":\"wide\",\"version\":1}\n").unwrap();
    reader.read_line(&mut line).unwrap();
    assert!(line.contains("wide@1"), "{line}");

    let reference = CompiledNetwork::from_plan(spec.plan().unwrap(), &tf).unwrap();
    assert_eq!(reference.num_classes(), 6, "the plan declares the head width");
    let img = synth_image(7);
    let want = reference.infer_batch(&img).unwrap();
    assert_eq!(want.len(), 6);

    let req = format!(
        "{{\"op\":\"classify\",\"model\":\"wide\",\"pixels\":{}}}\n",
        json_image(&img)
    );
    conn.write_all(req.as_bytes()).unwrap();
    line.clear();
    reader.read_line(&mut line).unwrap();
    let j = Json::parse(&line).unwrap();
    assert!(j.get("ok").unwrap().as_bool().unwrap(), "{line}");
    let logits: Vec<f32> = j
        .get("logits")
        .unwrap()
        .as_arr()
        .unwrap()
        .iter()
        .map(|v| v.as_f64().unwrap() as f32)
        .collect();
    assert_eq!(logits.len(), 6, "six declared classes, six logits on the wire: {line}");
    assert_eq!(
        logits.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
        want.iter().map(|v| v.to_bits()).collect::<Vec<_>>()
    );
    // argmax may land beyond the server's four label strings; the
    // response still carries the honest class index (label degrades
    // to "?", never panics and never mislabels)
    let class = j.get("class").unwrap().as_usize().unwrap();
    assert!(class < 6, "{line}");
    let label = j.get("label").unwrap().as_str().unwrap();
    if class >= 4 {
        assert_eq!(label, "?", "{line}");
    }
    stop.store(true, Ordering::Relaxed);
}

#[test]
fn pjrt_backend_serves_through_router() {
    if !std::path::Path::new("artifacts/manifest.json").exists() {
        eprintln!("skipping: run `make artifacts` first");
        return;
    }
    let artifacts = Arc::new(Artifacts::load("artifacts").unwrap());
    let names: Vec<(usize, String)> = artifacts
        .models
        .iter()
        .filter(|m| m.scheme == "rgb" && m.kind == "bcnn_ref")
        .map(|m| (m.batch, m.name.clone()))
        .collect();
    assert!(!names.is_empty());
    let backend: Arc<dyn InferBackend> = Arc::new(
        bcnn::coordinator::RuntimeBackend::spawn(Arc::clone(&artifacts), names, "pjrt/rgb")
            .unwrap(),
    );
    let router = Arc::new(
        Router::builder()
            .policy(BatchPolicy {
                max_batch: 4,
                max_wait: std::time::Duration::from_millis(1),
                ..BatchPolicy::default()
            })
            .variant("rgb", backend)
            .build(),
    );
    // engine with the SAME exported weights must agree with the HLO path
    let net = bcnn::bnn::network::BcnnNetwork::load(
        artifacts.path_of("weights_bcnn_rgb.bcnt"),
        Scheme::Rgb,
    )
    .unwrap();
    for i in 0..8u64 {
        let img = synth_image(i);
        let resp = router.infer_blocking("rgb", img.clone()).unwrap();
        assert!(resp.error.is_none(), "{:?}", resp.error);
        let (want, _) = net.forward(&img);
        for k in 0..4 {
            assert!(
                (resp.logits[k] - want[k]).abs() <= 1e-3 + 1e-3 * want[k].abs(),
                "image {i} logit {k}: pjrt {} vs engine {}",
                resp.logits[k],
                want[k]
            );
        }
    }
    router.shutdown();
}

#[test]
fn forced_trace_classify_reports_a_monotone_gap_accounted_timeline() {
    // acceptance (ISSUE 9 tentpole): `"trace": true` on a classify over
    // a real socket echoes a span timeline whose offsets are monotone,
    // whose stage set runs parse → admit → queue → batch → per-step
    // exec → logits, and whose total brackets the lane's own e2e
    // measurement while fitting inside the client-observed wall time.
    use std::io::{BufRead, BufReader, Write};
    use std::net::TcpStream;
    use std::sync::atomic::{AtomicBool, Ordering};

    use bcnn::util::json::Json;

    let server = Arc::new(Server::new(engine_registry(1), classes()));
    let stop = Arc::new(AtomicBool::new(false));
    let addr = Arc::clone(&server).serve("127.0.0.1:0", 2, Arc::clone(&stop)).unwrap();

    let mut conn = TcpStream::connect(addr).unwrap();
    let mut reader = BufReader::new(conn.try_clone().unwrap());
    let img = json_image(&synth_image(3));
    let req =
        format!("{{\"op\":\"classify\",\"model\":\"rgb\",\"trace\":true,\"pixels\":{img}}}\n");
    let started = std::time::Instant::now();
    conn.write_all(req.as_bytes()).unwrap();
    let mut line = String::new();
    reader.read_line(&mut line).unwrap();
    let elapsed_us = started.elapsed().as_micros() as f64;

    let j = Json::parse(&line).unwrap();
    assert!(j.get("ok").unwrap().as_bool().unwrap(), "{line}");
    let trace = j.get("trace").unwrap();
    assert_eq!(trace.get("model").unwrap().as_str().unwrap(), "rgb@1", "{line}");
    assert!(trace.get("id").unwrap().as_usize().unwrap() > 0, "a real coordinator id: {line}");
    let spans = trace.get("spans").unwrap().as_arr().unwrap();
    let labels: Vec<&str> =
        spans.iter().map(|s| s.get("label").unwrap().as_str().unwrap()).collect();
    assert_eq!(&labels[..4], &["parsed", "admitted", "enqueued", "batch_formed"], "{line}");
    assert!(labels.iter().any(|l| l.starts_with("exec:")), "per-step exec spans: {line}");
    assert_eq!(*labels.last().unwrap(), "logits", "the inline echo ends at logits: {line}");
    // monotone offsets; gap-accounted: the last offset IS the total
    let offs: Vec<f64> = spans.iter().map(|s| s.get("us").unwrap().as_f64().unwrap()).collect();
    assert!(offs.windows(2).all(|w| w[0] <= w[1]), "offsets ran backwards: {line}");
    let total_us = trace.get("total_us").unwrap().as_f64().unwrap();
    assert!(total_us > 0.0, "{line}");
    assert_eq!(total_us, *offs.last().unwrap(), "{line}");

    // the trace starts before admission and ends at logits, so its
    // total must cover the lane's recorded e2e latency (within clock
    // slack) and fit inside what the client saw on the wire
    line.clear();
    conn.write_all(b"{\"op\":\"stats\"}\n").unwrap();
    reader.read_line(&mut line).unwrap();
    let stats = Json::parse(&line).unwrap();
    let e2e_us = stats
        .get("stats")
        .unwrap()
        .get("lanes")
        .unwrap()
        .get("rgb@1")
        .unwrap()
        .get("e2e_us")
        .unwrap()
        .get("mean")
        .unwrap()
        .as_f64()
        .unwrap();
    assert!(total_us + 500.0 >= e2e_us, "trace total {total_us}µs < lane e2e {e2e_us}µs");
    assert!(total_us <= elapsed_us + 500.0, "trace total {total_us}µs > wall {elapsed_us}µs");
    stop.store(true, Ordering::Relaxed);
}

#[test]
fn trace_dump_drains_stored_traces_with_written_spans_and_model_filter() {
    // forced traces are stored as well as echoed; the stored copy gains
    // the terminal `written` span (stamped after the response hit the
    // socket), trace_dump's model filter leaves other lanes' traces
    // buffered, and draining empties the ring.
    use std::io::{BufRead, BufReader, Write};
    use std::net::TcpStream;
    use std::sync::atomic::{AtomicBool, Ordering};

    use bcnn::util::json::Json;

    let server = Arc::new(Server::new(engine_registry(1), classes()));
    let stop = Arc::new(AtomicBool::new(false));
    let addr = Arc::clone(&server).serve("127.0.0.1:0", 2, Arc::clone(&stop)).unwrap();

    let mut conn = TcpStream::connect(addr).unwrap();
    let mut reader = BufReader::new(conn.try_clone().unwrap());
    let img = json_image(&synth_image(5));
    let mut line = String::new();
    for model in ["rgb", "lbp"] {
        line.clear();
        let req = format!(
            "{{\"op\":\"classify\",\"model\":\"{model}\",\"trace\":true,\"pixels\":{img}}}\n"
        );
        conn.write_all(req.as_bytes()).unwrap();
        reader.read_line(&mut line).unwrap();
        assert!(line.contains("label"), "{line}");
    }

    // the session loop stores each trace after writing its response, so
    // by the time THIS request is read both traces are buffered
    line.clear();
    conn.write_all(b"{\"op\":\"trace_dump\",\"model\":\"lbp@1\"}\n").unwrap();
    reader.read_line(&mut line).unwrap();
    let j = Json::parse(&line).unwrap();
    let traces = j.get("traces").unwrap().as_arr().unwrap();
    assert_eq!(traces.len(), 1, "filter matches exactly the lbp trace: {line}");
    assert_eq!(j.get("dropped").unwrap().as_usize().unwrap(), 0, "{line}");
    assert_eq!(traces[0].get("model").unwrap().as_str().unwrap(), "lbp@1", "{line}");
    let spans = traces[0].get("spans").unwrap().as_arr().unwrap();
    let last = spans.last().unwrap().get("label").unwrap().as_str().unwrap();
    assert_eq!(last, "written", "stored traces carry the write-back span: {line}");

    // the rgb trace stayed buffered through the filtered drain
    line.clear();
    conn.write_all(b"{\"op\":\"trace_dump\"}\n").unwrap();
    reader.read_line(&mut line).unwrap();
    let j = Json::parse(&line).unwrap();
    let traces = j.get("traces").unwrap().as_arr().unwrap();
    assert_eq!(traces.len(), 1, "{line}");
    assert_eq!(traces[0].get("model").unwrap().as_str().unwrap(), "rgb@1", "{line}");

    // draining drained: the ring is now empty
    line.clear();
    conn.write_all(b"{\"op\":\"trace_dump\"}\n").unwrap();
    reader.read_line(&mut line).unwrap();
    let j = Json::parse(&line).unwrap();
    assert!(j.get("traces").unwrap().as_arr().unwrap().is_empty(), "{line}");
    stop.store(true, Ordering::Relaxed);
}

#[test]
fn sampling_off_steady_state_stores_no_traces_and_reuses_arenas() {
    // acceptance (ISSUE 9): with `--trace-sample 0` (the default) the
    // steady-state serving path allocates nothing for tracing — every
    // response is trace-free, the trace ring stays empty, and the
    // backend's scratch pool stops growing after warmup.
    use std::io::{BufRead, BufReader, Write};
    use std::net::TcpStream;
    use std::sync::atomic::{AtomicBool, Ordering};

    use bcnn::util::json::Json;

    let engine = Arc::new(EngineBackend::bcnn(synth_bcnn_network(Scheme::Rgb, 21), 2));
    let registry = ModelRegistry::builder()
        .policy(BatchPolicy {
            max_batch: 1,
            max_wait: std::time::Duration::from_millis(1),
            ..BatchPolicy::default()
        })
        .queue_capacity(512)
        .build();
    let be: Arc<dyn InferBackend> = Arc::clone(&engine) as Arc<dyn InferBackend>;
    registry.publish_backend("rgb", 1, "bcnn", "rgb", None, be).unwrap();
    let server = Arc::new(Server::new(registry, classes())); // sampling off by default
    let stop = Arc::new(AtomicBool::new(false));
    let addr = Arc::clone(&server).serve("127.0.0.1:0", 2, Arc::clone(&stop)).unwrap();

    let mut conn = TcpStream::connect(addr).unwrap();
    let mut reader = BufReader::new(conn.try_clone().unwrap());
    let mut line = String::new();
    let mut classify_synth = |index: u64| {
        line.clear();
        let req = format!("{{\"op\":\"classify_synth\",\"model\":\"rgb\",\"index\":{index}}}\n");
        conn.write_all(req.as_bytes()).unwrap();
        reader.read_line(&mut line).unwrap();
        assert!(line.contains("label"), "{line}");
        assert!(!line.contains("\"trace\""), "untraced responses carry no trace key: {line}");
    };
    for i in 0..8 {
        classify_synth(i); // warm the scratch pool to steady state
    }
    let warmed = engine.pool_stats().unwrap();
    assert!(warmed.arenas >= 1, "warmup parked at least one arena");
    for i in 8..72 {
        classify_synth(i);
    }
    assert_eq!(
        engine.pool_stats().unwrap(),
        warmed,
        "steady-state traffic with sampling off must not grow the arena pool"
    );
    line.clear();
    conn.write_all(b"{\"op\":\"trace_dump\"}\n").unwrap();
    reader.read_line(&mut line).unwrap();
    let j = Json::parse(&line).unwrap();
    assert!(j.get("traces").unwrap().as_arr().unwrap().is_empty(), "{line}");
    assert_eq!(j.get("dropped").unwrap().as_usize().unwrap(), 0, "{line}");
    stop.store(true, Ordering::Relaxed);
}
