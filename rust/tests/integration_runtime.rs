//! Integration tests over the real AOT artifacts (`make artifacts` must
//! have run — these tests are skipped politely when artifacts/ is absent).
//!
//! The decisive correctness signal: the HLO executables (lowered from the
//! JAX/Pallas model) and the pure-Rust engine produce the SAME logits on
//! the same weights, for every scheme — and both match the
//! `expected_logits.bcnt` reference computed by jnp at export time.

use bcnn::bnn::network::{argmax, BcnnNetwork, FloatNetwork};
use bcnn::dataset::testset::{ExpectedLogits, TestSet};
use bcnn::input::binarize::Scheme;
use bcnn::runtime::{Artifacts, ModelRuntime};

const DIR: &str = "artifacts";

fn artifacts() -> Option<Artifacts> {
    if !std::path::Path::new(DIR).join("manifest.json").exists() {
        eprintln!("skipping: run `make artifacts` first");
        return None;
    }
    Some(Artifacts::load(DIR).expect("manifest parses"))
}

#[test]
fn manifest_inventory_is_complete() {
    let Some(a) = artifacts() else { return };
    assert_eq!(a.classes, vec!["bus", "normal", "truck", "van"]);
    // 4 float batches + per scheme: 1 pallas + 4 ref batches
    assert!(a.models.len() >= 4 + 4 * 5, "got {} models", a.models.len());
    assert_eq!(a.layers.len(), 14);
    for m in &a.models {
        assert!(a.path_of(&m.file).exists(), "{} missing", m.file);
        assert!(a.path_of(&m.weights_file).exists());
    }
}

#[test]
fn rust_engine_matches_expected_logits_all_schemes() {
    let Some(a) = artifacts() else { return };
    let exp = ExpectedLogits::load(a.expected_logits_path().unwrap()).unwrap();
    for scheme in Scheme::ALL {
        let tf_path = a.path_of(&format!("weights_bcnn_{}.bcnt", scheme.name()));
        let net = BcnnNetwork::load(&tf_path, scheme).unwrap();
        let want = exp.logits(&format!("logits_bcnn_{}", scheme.name())).unwrap();
        for i in 0..exp.n {
            let (logits, _) = net.forward(exp.image(i));
            let w = &want[i * 4..(i + 1) * 4];
            for k in 0..4 {
                assert!(
                    (logits[k] - w[k]).abs() <= 1e-3 + 1e-3 * w[k].abs(),
                    "{scheme:?} image {i} logit {k}: rust {} vs jax {}",
                    logits[k],
                    w[k]
                );
            }
        }
    }
}

#[test]
fn rust_float_network_matches_expected_logits() {
    let Some(a) = artifacts() else { return };
    let exp = ExpectedLogits::load(a.expected_logits_path().unwrap()).unwrap();
    let net = FloatNetwork::load(a.path_of("weights_float.bcnt")).unwrap();
    let want = exp.logits("logits_float").unwrap();
    for i in 0..exp.n {
        let (logits, _) = net.forward(exp.image(i));
        let w = &want[i * 4..(i + 1) * 4];
        for k in 0..4 {
            // float path accumulates in different order than XLA: allow
            // proportional tolerance
            assert!(
                (logits[k] - w[k]).abs() <= 1e-2 + 1e-3 * w[k].abs(),
                "float image {i} logit {k}: rust {} vs jax {}",
                logits[k],
                w[k]
            );
        }
    }
}

#[test]
fn hlo_ref_models_match_rust_engine() {
    let Some(a) = artifacts() else { return };
    let client = bcnn::runtime::client::cpu_client().unwrap();
    let exp = ExpectedLogits::load(a.expected_logits_path().unwrap()).unwrap();
    for scheme in ["rgb", "none", "lbp", "gray"] {
        let model = format!("model_bcnn_{scheme}_ref_b1");
        let rt = ModelRuntime::load(&client, &a, &model).unwrap();
        let net = BcnnNetwork::load(
            a.path_of(&format!("weights_bcnn_{scheme}.bcnt")),
            Scheme::parse(scheme).unwrap(),
        )
        .unwrap();
        for i in 0..exp.n.min(3) {
            let hlo = rt.infer(exp.image(i)).unwrap();
            let (rust, _) = net.forward(exp.image(i));
            for k in 0..4 {
                assert!(
                    (hlo[k] - rust[k]).abs() <= 1e-3 + 1e-3 * rust[k].abs(),
                    "{model} image {i} logit {k}: hlo {} vs rust {}",
                    hlo[k],
                    rust[k]
                );
            }
        }
    }
}

#[test]
fn hlo_pallas_model_matches_ref_model() {
    let Some(a) = artifacts() else { return };
    let client = bcnn::runtime::client::cpu_client().unwrap();
    let exp = ExpectedLogits::load(a.expected_logits_path().unwrap()).unwrap();
    let pallas = ModelRuntime::load(&client, &a, "model_bcnn_rgb_b1").unwrap();
    let reference = ModelRuntime::load(&client, &a, "model_bcnn_rgb_ref_b1").unwrap();
    for i in 0..exp.n.min(3) {
        let p = pallas.infer(exp.image(i)).unwrap();
        let r = reference.infer(exp.image(i)).unwrap();
        assert_eq!(p, r, "pallas vs ref logits differ on image {i}");
    }
}

#[test]
fn hlo_float_model_runs_and_classifies() {
    let Some(a) = artifacts() else { return };
    let client = bcnn::runtime::client::cpu_client().unwrap();
    let rt = ModelRuntime::load(&client, &a, "model_float_b1").unwrap();
    let exp = ExpectedLogits::load(a.expected_logits_path().unwrap()).unwrap();
    let want = exp.logits("logits_float").unwrap();
    // batch-1 float model input is (1,96,96,3)
    let hlo = rt.infer(exp.image(0)).unwrap();
    assert_eq!(hlo.len(), 4);
    for k in 0..4 {
        assert!(
            (hlo[k] - want[k]).abs() <= 1e-3 + 1e-3 * want[k].abs(),
            "logit {k}: {} vs {}",
            hlo[k],
            want[k]
        );
    }
}

#[test]
fn batched_hlo_matches_singles() {
    let Some(a) = artifacts() else { return };
    let client = bcnn::runtime::client::cpu_client().unwrap();
    let b1 = ModelRuntime::load(&client, &a, "model_bcnn_rgb_ref_b1").unwrap();
    let b4 = ModelRuntime::load(&client, &a, "model_bcnn_rgb_ref_b4").unwrap();
    let ts = TestSet::load(a.testset_path().unwrap()).unwrap();
    let n = 4;
    let mut batch = Vec::with_capacity(n * 96 * 96 * 3);
    for i in 0..n {
        batch.extend_from_slice(ts.image(i));
    }
    let batched = b4.infer(&batch).unwrap();
    for i in 0..n {
        let single = b1.infer(ts.image(i)).unwrap();
        // bit pipeline identical; the float fc tail may round differently
        // across batch layouts
        for k in 0..4 {
            assert!(
                (batched[i * 4 + k] - single[k]).abs() <= 1e-5 + 1e-5 * single[k].abs(),
                "image {i} logit {k}: {} vs {}",
                batched[i * 4 + k],
                single[k]
            );
        }
    }
}

#[test]
fn engine_accuracy_on_testset_is_sane() {
    // with untrained (random) weights accuracy hovers near chance; with
    // trained weights it must beat chance substantially.  Either way the
    // pipeline must classify every image without error.
    let Some(a) = artifacts() else { return };
    let ts = TestSet::load(a.testset_path().unwrap()).unwrap();
    let net = BcnnNetwork::load(a.path_of("weights_bcnn_rgb.bcnt"), Scheme::Rgb).unwrap();
    let n = ts.len().min(64);
    let mut correct = 0usize;
    for i in 0..n {
        let (logits, _) = net.forward(ts.image(i));
        assert!(logits.iter().all(|v| v.is_finite()));
        correct += usize::from(argmax(&logits) as i32 == ts.labels[i]);
    }
    let trained = a.trained.iter().any(|(k, t)| k == "rgb" && *t);
    if trained {
        assert!(correct * 2 > n, "trained rgb accuracy {}/{n} below 50%", correct);
    }
}

#[test]
fn legacy_containers_compile_through_the_layer_graph_planner() {
    // artifacts-independent: the synthesized legacy specs must plan the
    // exact legacy weight names (so every aot.py container keeps
    // loading) with a liveness-sized arena far below the 11 hand-named
    // roles the pre-graph ForwardScratch carried.
    use bcnn::bnn::graph::NetworkSpec;
    for scheme in Scheme::ALL {
        let plan = NetworkSpec::legacy_bcnn(scheme).plan().unwrap();
        assert!(
            plan.num_buffers() <= 5,
            "{scheme:?}: planned {} slots, expected <= 5",
            plan.num_buffers()
        );
        assert!(plan.weights.iter().any(|w| w.name == "wfc1_packed"));
    }
    let plan = NetworkSpec::legacy_float().plan().unwrap();
    assert_eq!(plan.nbufs, [3, 0, 0]);
    // when real artifacts exist, the compiled plan must bind them
    let Some(a) = artifacts() else { return };
    for scheme in Scheme::ALL {
        let tf_path = a.path_of(&format!("weights_bcnn_{}.bcnt", scheme.name()));
        let net = BcnnNetwork::load(&tf_path, scheme).unwrap();
        assert_eq!(net.compiled().plan().classes, 4);
    }
}
