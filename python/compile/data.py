"""SynthVehicles — deterministic procedural stand-in for the proprietary
vehicle dataset of Huttunen et al. [12] used by the paper.

The paper trains on 6555 camera images (96x96x3) manually labelled into
four classes: *bus, normal, truck, van*. That dataset is not public, so we
render a synthetic equivalent: side-view vehicles with class-dependent
geometry on a road/sky background, with pose, scale, colour and lighting
jitter plus sensor noise. The renderer is fully vectorized numpy and
deterministic: image ``i`` under seed ``s`` is always the same bits.

Class geometry (side view, x = direction of travel):
  * bus    — single long, tall body; a row of many square windows; two
             wheels far apart.
  * normal — low body with a shorter trapezoid cabin on top; two wheels.
  * truck  — short cab with windshield + separate taller cargo box; the
             box/cab gap is the discriminative feature; two/three wheels.
  * van    — one tall box with a short sloped hood; one side window near
             the front; two wheels.

The augmentation mirrors the paper: horizontal flip of every training
image, plus filtering with a 2D Gaussian (sigma = 0.5) applied to a
subset, growing the training set from 90% of 6555 to ~14k images.
"""

from __future__ import annotations

from dataclasses import dataclass

import numpy as np

CLASSES = ("bus", "normal", "truck", "van")
NUM_CLASSES = 4
IMG_H = 96
IMG_W = 96
IMG_C = 3
DATASET_SIZE = 6555  # same cardinality as the paper's dataset
TEST_FRACTION = 0.10  # paper: 90% train / 10% test


# ---------------------------------------------------------------------------
# Deterministic per-image RNG (SplitMix64 — also implemented in rust/util/rng)
# ---------------------------------------------------------------------------

_MASK = (1 << 64) - 1


def _splitmix64_stream(seed: int, n: int) -> np.ndarray:
    """First ``n`` outputs of SplitMix64 starting from ``seed`` as u64."""
    out = np.empty(n, dtype=np.uint64)
    x = seed & _MASK
    for i in range(n):
        x = (x + 0x9E3779B97F4A7C15) & _MASK
        z = x
        z = ((z ^ (z >> 30)) * 0xBF58476D1CE4E5B9) & _MASK
        z = ((z ^ (z >> 27)) * 0x94D049BB133111EB) & _MASK
        out[i] = z ^ (z >> 31)
    return out


def _unit_floats(seed: int, n: int) -> np.ndarray:
    """n deterministic floats in [0, 1) from SplitMix64."""
    return (_splitmix64_stream(seed, n) >> np.uint64(11)).astype(np.float64) / float(1 << 53)


# ---------------------------------------------------------------------------
# Rendering helpers
# ---------------------------------------------------------------------------


def _rect(xx, yy, x0, y0, x1, y1):
    """Boolean mask of an axis-aligned rectangle (inclusive-exclusive)."""
    return (xx >= x0) & (xx < x1) & (yy >= y0) & (yy < y1)


def _disc(xx, yy, cx, cy, r):
    return (xx - cx) ** 2 + (yy - cy) ** 2 <= r * r


def _paint(img, mask, color):
    img[mask] = color


@dataclass(frozen=True)
class Sample:
    image: np.ndarray  # (96, 96, 3) float32 in [0, 1]
    label: int


def render_vehicle(index: int, seed: int = 0xB0C4) -> Sample:
    """Render dataset image ``index`` deterministically.

    The label is ``index % 4`` so the dataset is perfectly class-balanced;
    all remaining randomness is drawn from SplitMix64(seed ^ index-stream).
    """
    label = index % NUM_CLASSES
    u = _unit_floats((seed << 20) ^ (index * 0x9E37 + 0x1234_5678), 32)

    yy, xx = np.mgrid[0:IMG_H, 0:IMG_W].astype(np.float32)
    img = np.empty((IMG_H, IMG_W, IMG_C), dtype=np.float32)

    # --- background: sky gradient + road ---------------------------------
    horizon = 52 + int(u[0] * 10)  # 52..61
    sky_top = np.array([0.45 + 0.2 * u[1], 0.55 + 0.2 * u[2], 0.75 + 0.2 * u[3]])
    road = 0.25 + 0.15 * u[4]
    t = (yy / max(horizon, 1)).clip(0.0, 1.0)[..., None]
    img[:] = sky_top * (1.0 - 0.35 * t)
    road_mask = yy >= horizon
    img[road_mask] = np.array([road, road, road * 1.02])

    # light clutter: a couple of background blobs (buildings / bushes)
    for b in range(2):
        bx = int(u[5 + b] * IMG_W)
        bw = 8 + int(u[7 + b] * 16)
        bh = 6 + int(u[9 + b] * 12)
        shade = 0.35 + 0.3 * u[11 + b]
        m = _rect(xx, yy, bx, horizon - bh, bx + bw, horizon)
        _paint(img, m, np.array([shade, shade * 0.95, shade * 0.9]))

    # --- vehicle geometry --------------------------------------------------
    scale = 0.75 + 0.4 * u[13]  # overall size jitter
    cx = 48 + (u[14] - 0.5) * 16  # horizontal jitter
    ground = horizon + 14 + (u[15] - 0.5) * 8  # wheel contact line
    body = np.array([0.15 + 0.75 * u[16], 0.15 + 0.75 * u[17], 0.15 + 0.75 * u[18]])
    win = np.array([0.65, 0.8, 0.9]) * (0.7 + 0.3 * u[19])
    dark = np.array([0.06, 0.06, 0.07])

    def px(v):
        return float(v) * scale

    wheel_r = px(5.0)
    wy = ground - wheel_r * 0.6

    if label == 0:  # bus: long tall single body, window row
        half_len, height = px(34), px(26)
        x0, x1 = cx - half_len, cx + half_len
        y1 = ground - px(3)
        y0 = y1 - height
        _paint(img, _rect(xx, yy, x0, y0, x1, y1), body)
        # window row
        wn = 5
        wgap = (2 * half_len) / (wn + 1)
        for w in range(wn):
            wx0 = x0 + wgap * (w + 0.6)
            _paint(img, _rect(xx, yy, wx0, y0 + px(4), wx0 + wgap * 0.6, y0 + px(11)), win)
        wheels = [x0 + px(8), x1 - px(8)]
    elif label == 1:  # normal car: low body + cabin
        half_len, height = px(24), px(10)
        x0, x1 = cx - half_len, cx + half_len
        y1 = ground - px(2)
        y0 = y1 - height
        _paint(img, _rect(xx, yy, x0, y0, x1, y1), body)
        # cabin: trapezoid approximated by a shorter rectangle + windows
        cx0, cx1 = cx - half_len * 0.45, cx + half_len * 0.45
        cy0 = y0 - px(9)
        _paint(img, _rect(xx, yy, cx0, cy0, cx1, y0), body * 0.92)
        _paint(img, _rect(xx, yy, cx0 + px(2), cy0 + px(2), cx - px(1), y0 - px(1)), win)
        _paint(img, _rect(xx, yy, cx + px(1), cy0 + px(2), cx1 - px(2), y0 - px(1)), win)
        wheels = [x0 + px(7), x1 - px(7)]
    elif label == 2:  # truck: cab + separate cargo box with a visible gap
        cab_len, cab_h = px(12), px(16)
        box_len, box_h = px(30), px(24)
        gap = px(3)
        x_cab1 = cx + cab_len + box_len / 2 + gap  # cab at the front (right)
        x_cab0 = x_cab1 - cab_len
        xb0 = x_cab0 - gap - box_len
        xb1 = x_cab0 - gap
        y1 = ground - px(3)
        _paint(img, _rect(xx, yy, xb0, y1 - box_h, xb1, y1), body)
        _paint(img, _rect(xx, yy, x_cab0, y1 - cab_h, x_cab1, y1), body * 0.85)
        _paint(img, _rect(xx, yy, x_cab0 + px(2), y1 - cab_h + px(2), x_cab1 - px(2), y1 - cab_h + px(8)), win)
        wheels = [xb0 + px(6), xb1 - px(6), x_cab1 - px(5)]
    else:  # van: tall box + short hood, front side window
        half_len, height = px(26), px(22)
        x0, x1 = cx - half_len, cx + half_len
        y1 = ground - px(2)
        y0 = y1 - height
        _paint(img, _rect(xx, yy, x0, y0, x1, y1), body)
        # short hood step at the front
        _paint(img, _rect(xx, yy, x1, y1 - px(8), x1 + px(6), y1), body * 0.95)
        _paint(img, _rect(xx, yy, x1 - px(10), y0 + px(3), x1 - px(2), y0 + px(11)), win)
        wheels = [x0 + px(7), x1 - px(7)]

    for wxc in wheels:
        _paint(img, _disc(xx, yy, wxc, wy, wheel_r), dark)
        _paint(img, _disc(xx, yy, wxc, wy, wheel_r * 0.45), np.array([0.5, 0.5, 0.52]))

    # --- sensor noise + global illumination jitter -------------------------
    gain = 0.85 + 0.3 * u[20]
    noise = (
        _unit_floats((seed << 21) ^ (index * 0x85EB + 77), IMG_H * IMG_W)
        .reshape(IMG_H, IMG_W)
        .astype(np.float32)
    )
    img = img * gain + (noise[..., None] - 0.5) * 0.06
    return Sample(image=np.clip(img, 0.0, 1.0).astype(np.float32), label=label)


# ---------------------------------------------------------------------------
# Dataset assembly, split, augmentation
# ---------------------------------------------------------------------------


def gaussian_blur_05(img: np.ndarray) -> np.ndarray:
    """2D Gaussian filter with sigma = 0.5 (paper's augmentation filter).

    A 3-tap separable kernel captures >99.7% of the mass at sigma=0.5.
    """
    g = np.array([np.exp(-2.0), 1.0, np.exp(-2.0)], dtype=np.float32)
    g /= g.sum()
    # reflect-pad then convolve along H and W
    p = np.pad(img, ((1, 1), (0, 0), (0, 0)), mode="reflect")
    img = p[:-2] * g[0] + p[1:-1] * g[1] + p[2:] * g[2]
    p = np.pad(img, ((0, 0), (1, 1), (0, 0)), mode="reflect")
    img = p[:, :-2] * g[0] + p[:, 1:-1] * g[1] + p[:, 2:] * g[2]
    return img.astype(np.float32)


def generate(n: int = DATASET_SIZE, seed: int = 0xB0C4):
    """Render ``n`` images; returns (images (n,96,96,3) f32, labels (n,) i32)."""
    images = np.empty((n, IMG_H, IMG_W, IMG_C), dtype=np.float32)
    labels = np.empty(n, dtype=np.int32)
    for i in range(n):
        s = render_vehicle(i, seed)
        images[i] = s.image
        labels[i] = s.label
    return images, labels


def split_indices(n: int = DATASET_SIZE, seed: int = 0xB0C4):
    """Deterministic 90/10 train/test split (paper's protocol)."""
    u = _unit_floats((seed << 22) ^ 0xDEAD_BEEF, n)
    order = np.argsort(u, kind="stable")
    n_test = int(round(n * TEST_FRACTION))
    return np.sort(order[n_test:]), np.sort(order[:n_test])


def augment(images: np.ndarray, labels: np.ndarray, seed: int = 0xB0C4):
    """Paper's augmentation: flip everything, blur a subset.

    Returns roughly 2.4x the input count (paper: 5900 -> 14108 ~ 2.39x).
    """
    flipped = images[:, :, ::-1, :]
    u = _unit_floats((seed << 23) ^ 0x0A0B_0C0D, len(images))
    blur_sel = u < 0.4
    blurred = np.stack([gaussian_blur_05(im) for im in images[blur_sel]]) if blur_sel.any() else np.empty((0, IMG_H, IMG_W, IMG_C), np.float32)
    out_images = np.concatenate([images, flipped, blurred], axis=0)
    out_labels = np.concatenate([labels, labels, labels[blur_sel]], axis=0)
    return out_images, out_labels


def load_splits(n: int = DATASET_SIZE, seed: int = 0xB0C4, augment_train: bool = True):
    """Full pipeline: render, split 90/10, augment the training half."""
    images, labels = generate(n, seed)
    tr, te = split_indices(n, seed)
    x_train, y_train = images[tr], labels[tr]
    if augment_train:
        x_train, y_train = augment(x_train, y_train, seed)
    return (x_train, y_train), (images[te], labels[te])
