"""Layer 2 — the vehicle-classifier network of Huttunen et al. [12] in
both full-precision and binarized (BCNN) forms.

Architecture (paper Section 2.1 + Table 2):

    input 96x96x3
    conv1  5x5, C_in -> 32, 'same'     (C_in = 3, or 1 for gray scheme)
    pool1  2x2 max                     -> 48x48x32
    conv2  5x5, 32 -> 32, 'same'
    pool2  2x2 max                     -> 24x24x32
    fc1    18432 -> 100
    fc2    100 -> 100
    fc3    100 -> 4                     (bus / normal / truck / van)

Full-precision: ReLU activations, biases, no batch norm (the 2016-era
reference net).  BCNN: `sign` activations, **no ReLU** (paper Section
2.1), binary conv/fc1 weights, float fc2/fc3 ("the last 2 fully-connected
layers ... more efficient to implement them on the CPU").  Like the BNN
lineage the paper follows ([11] Hubara et al.), the binarized net needs a
per-channel affine normalization before each sign to be trainable; at
inference it folds into an integer threshold per channel
(:func:`kernels.ref.fold_bn_to_threshold`) so the deployed network remains
pure xnor-popcount + compare.  The paper is silent on this detail; we
document it as a faithful-to-[11] addition (DESIGN.md §2).

Two inference paths compute identical bits:

* ``bcnn_infer_ref``    — pure jnp (vectorizable over a batch); and
* ``bcnn_infer_pallas`` — the Pallas kernel pipeline (single image),
  used for the AOT artifacts that the Rust runtime serves.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from . import binarize_input
from .kernels import bgemm as k_bgemm
from .kernels import fc_packed as k_fc
from .kernels import im2col_pack as k_im2col
from .kernels import maxpool as k_pool
from .kernels import ref
from .kernels import sign_pack as k_sign

IMG_H, IMG_W, IMG_C = 96, 96, 3
K = 5
CONV1_OUT = 32
CONV2_OUT = 32
FC1_OUT = 100
FC2_OUT = 100
NUM_CLASSES = 4
BN_EPS = 1e-5
BN_MOMENTUM = 0.9


# ---------------------------------------------------------------------------
# straight-through estimator (paper Section 2.1)
# ---------------------------------------------------------------------------


@jax.custom_vjp
def ste_sign(x):
    """sign with pass-through gradient (paper: d sign / dx := identity)."""
    return jnp.where(x > 0, 1.0, -1.0).astype(x.dtype)


def _ste_fwd(x):
    return ste_sign(x), None


def _ste_bwd(_, g):
    return (g,)  # identity, no clipping — matches the paper's text


ste_sign.defvjp(_ste_fwd, _ste_bwd)


@jax.custom_vjp
def ste_sign_clip(x):
    """sign with clipped pass-through (Hubara et al. [11] variant)."""
    return jnp.where(x > 0, 1.0, -1.0).astype(x.dtype)


def _stec_fwd(x):
    return ste_sign_clip(x), x


def _stec_bwd(x, g):
    return (g * (jnp.abs(x) <= 1.0).astype(g.dtype),)


ste_sign_clip.defvjp(_stec_fwd, _stec_bwd)


# ---------------------------------------------------------------------------
# parameter initialization
# ---------------------------------------------------------------------------


def _glorot(key, shape):
    fan_in = int(np.prod(shape[1:]))
    fan_out = shape[0]
    lim = float(np.sqrt(6.0 / (fan_in + fan_out)))
    return jax.random.uniform(key, shape, jnp.float32, -lim, lim)


def init_float_params(key):
    ks = jax.random.split(key, 5)
    return {
        "w1": _glorot(ks[0], (CONV1_OUT, K, K, IMG_C)),
        "b1": jnp.zeros((CONV1_OUT,), jnp.float32),
        "w2": _glorot(ks[1], (CONV2_OUT, K, K, CONV1_OUT)),
        "b2": jnp.zeros((CONV2_OUT,), jnp.float32),
        "wfc1": _glorot(ks[2], (FC1_OUT, 24 * 24 * CONV2_OUT)),
        "bfc1": jnp.zeros((FC1_OUT,), jnp.float32),
        "wfc2": _glorot(ks[3], (FC2_OUT, FC1_OUT)),
        "bfc2": jnp.zeros((FC2_OUT,), jnp.float32),
        "wfc3": _glorot(ks[4], (NUM_CLASSES, FC2_OUT)),
        "bfc3": jnp.zeros((NUM_CLASSES,), jnp.float32),
    }


def init_bcnn_params(key, scheme: str = "rgb"):
    c_in = binarize_input.input_channels(scheme)
    ks = jax.random.split(key, 6)
    p = {
        "w1": _glorot(ks[0], (CONV1_OUT, K, K, c_in)),
        "w2": _glorot(ks[1], (CONV2_OUT, K, K, CONV1_OUT)),
        "wfc1": _glorot(ks[2], (FC1_OUT, 24 * 24 * CONV2_OUT)),
        "wfc2": _glorot(ks[3], (FC2_OUT, FC1_OUT)),
        "bfc2": jnp.zeros((FC2_OUT,), jnp.float32),
        "wfc3": _glorot(ks[4], (NUM_CLASSES, FC2_OUT)),
        "bfc3": jnp.zeros((NUM_CLASSES,), jnp.float32),
        # batch-norm affine parameters (fold into thresholds at export)
        "bn1_gamma": jnp.ones((CONV1_OUT,), jnp.float32),
        "bn1_beta": jnp.zeros((CONV1_OUT,), jnp.float32),
        "bn2_gamma": jnp.ones((CONV2_OUT,), jnp.float32),
        "bn2_beta": jnp.zeros((CONV2_OUT,), jnp.float32),
        "bn3_gamma": jnp.ones((FC1_OUT,), jnp.float32),
        "bn3_beta": jnp.zeros((FC1_OUT,), jnp.float32),
    }
    if scheme in ("rgb", "gray"):
        n_t = 3 if scheme == "rgb" else 1
        # pixel range is [0,1]: initialize T near -mean so sign() is split
        p["input_t"] = jnp.full((n_t,), -0.5, jnp.float32)
    return p


def init_bn_state():
    return {
        "bn1_mean": jnp.zeros((CONV1_OUT,), jnp.float32),
        "bn1_var": jnp.ones((CONV1_OUT,), jnp.float32),
        "bn2_mean": jnp.zeros((CONV2_OUT,), jnp.float32),
        "bn2_var": jnp.ones((CONV2_OUT,), jnp.float32),
        "bn3_mean": jnp.zeros((FC1_OUT,), jnp.float32),
        "bn3_var": jnp.ones((FC1_OUT,), jnp.float32),
    }


# ---------------------------------------------------------------------------
# full-precision network (batched)
# ---------------------------------------------------------------------------


def _conv_same(x, w, pad_value: float = 0.0):
    """x: (N,H,W,C), w: (O,K,K,C) -> (N,H,W,O), 'same' with pad_value."""
    r = (w.shape[1] - 1) // 2
    xp = jnp.pad(x, ((0, 0), (r, r), (r, r), (0, 0)), constant_values=pad_value)
    return lax.conv_general_dilated(
        xp,
        jnp.transpose(w, (1, 2, 3, 0)),  # KKIO
        window_strides=(1, 1),
        padding="VALID",
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    )


def _maxpool_nhwc(x):
    n, h, w, c = x.shape
    return jnp.max(x.reshape(n, h // 2, 2, w // 2, 2, c), axis=(2, 4))


def float_forward(params, x):
    """Full-precision forward.  x: (N,96,96,3) -> logits (N,4)."""
    h = jax.nn.relu(_conv_same(x, params["w1"]) + params["b1"])
    h = _maxpool_nhwc(h)
    h = jax.nn.relu(_conv_same(h, params["w2"]) + params["b2"])
    h = _maxpool_nhwc(h)
    h = h.reshape(h.shape[0], -1)
    h = jax.nn.relu(h @ params["wfc1"].T + params["bfc1"])
    h = jax.nn.relu(h @ params["wfc2"].T + params["bfc2"])
    return h @ params["wfc3"].T + params["bfc3"]


# ---------------------------------------------------------------------------
# BCNN training forward (batched, STE, batch norm)
# ---------------------------------------------------------------------------


def _bn_apply(x, gamma, beta, mean, var):
    return gamma * (x - mean) * lax.rsqrt(var + BN_EPS) + beta


def _bn_train(x, gamma, beta, run_mean, run_var, axes):
    mean = jnp.mean(x, axis=axes)
    var = jnp.var(x, axis=axes)
    out = _bn_apply(x, gamma, beta, mean, var)
    new_mean = BN_MOMENTUM * run_mean + (1 - BN_MOMENTUM) * mean
    new_var = BN_MOMENTUM * run_var + (1 - BN_MOMENTUM) * var
    return out, new_mean, new_var


def bcnn_forward(params, state, x, scheme: str, train: bool, sign_fn=ste_sign):
    """BCNN forward.  x: (N,96,96,3) float in [0,1].

    Returns (logits (N,4), new_state).  ``train`` selects batch statistics
    (and updates the running ones) vs the frozen running statistics.
    """
    xb, _ = binarize_input.apply_scheme(scheme, x, params)
    if scheme in ("rgb", "gray"):
        # make the threshold trainable through the hard sign
        t = params["input_t"]
        if scheme == "rgb":
            xb = sign_fn(x + t.reshape(1, 1, 1, 3))
        else:
            gray = jnp.tensordot(x, binarize_input._LUMA, axes=([-1], [0]))
            xb = sign_fn(gray + t)[..., None]

    wb1 = sign_fn(params["w1"])
    wb2 = sign_fn(params["w2"])
    wbfc1 = sign_fn(params["wfc1"])

    if scheme == "none":
        y1 = _conv_same(x, wb1, 0.0)  # float input, binary weights, 0 pad
    else:
        y1 = _conv_same(xb, wb1, -1.0)  # binary domain pads with -1

    def bn_block(y, name, axes):
        g, b = params[f"{name}_gamma"], params[f"{name}_beta"]
        rm, rv = state[f"{name}_mean"], state[f"{name}_var"]
        if train:
            out, nm, nv = _bn_train(y, g, b, rm, rv, axes)
            return out, {f"{name}_mean": nm, f"{name}_var": nv}
        return _bn_apply(y, g, b, rm, rv), {}

    new_state = dict(state)
    y1, upd = bn_block(y1, "bn1", (0, 1, 2))
    new_state.update(upd)
    h1 = _maxpool_nhwc(sign_fn(y1))  # max == OR in the +-1 domain

    y2 = _conv_same(h1, wb2, -1.0)
    y2, upd = bn_block(y2, "bn2", (0, 1, 2))
    new_state.update(upd)
    h2 = _maxpool_nhwc(sign_fn(y2))

    y3 = h2.reshape(h2.shape[0], -1) @ wbfc1.T
    y3, upd = bn_block(y3, "bn3", (0,))
    new_state.update(upd)
    h3 = sign_fn(y3)

    h4 = sign_fn(h3 @ params["wfc2"].T + params["bfc2"])  # no ReLU anywhere
    logits = h4 @ params["wfc3"].T + params["bfc3"]
    return logits, new_state


# ---------------------------------------------------------------------------
# inference export: fold BN, pack weights
# ---------------------------------------------------------------------------


def export_inference_weights(params, state, scheme: str) -> dict:
    """Fold + pack trained parameters into the deployable representation.

    Returned dict (all numpy arrays; names match the Rust loader):
      w1_pm1      (32, 5,5,Cin) f32 +-1      conv1 weights, +-1 floats
      w1_packed   (32, NW1) u32              flattened-patch packing, B=32
      theta1      (32,) f32 / flip1 (32,) u32
      w2_packed   (32, 25*ceil(32/32)) u32   channel-packed per (dy,dx)
      theta2, flip2
      wfc1_packed (100, 576) u32             channel-packed per pixel
      theta3, flip3
      wfc2, bfc2, wfc3, bfc3                 float CPU tail
      input_t     (3,) or (1,) f32           (rgb / gray schemes only)
    """
    c_in = binarize_input.input_channels(scheme)
    d1 = K * K * c_in
    w1_pm1 = np.asarray(ref.sign_pm1(params["w1"]))
    w1_packed = np.asarray(
        ref.pack_bits(ref.pm1_to_bits(jnp.asarray(w1_pm1).reshape(CONV1_OUT, d1)), 32)
    )
    # conv2: channel-packed — bit order (dy, dx, c), one u32 per (dy,dx)
    w2_pm1 = np.asarray(ref.sign_pm1(params["w2"]))  # (32,5,5,32)
    w2_bits = jnp.asarray(w2_pm1).reshape(CONV2_OUT, K * K, CONV1_OUT)
    w2_packed = np.asarray(ref.pack_bits(ref.pm1_to_bits(w2_bits), 32)).reshape(
        CONV2_OUT, -1
    )
    # fc1: channel-packed per pixel — bit order (y, x, c)
    wfc1_pm1 = np.asarray(ref.sign_pm1(params["wfc1"]))  # (100, 18432)
    wfc1_bits = jnp.asarray(wfc1_pm1).reshape(FC1_OUT, 24 * 24, CONV2_OUT)
    wfc1_packed = np.asarray(ref.pack_bits(ref.pm1_to_bits(wfc1_bits), 32)).reshape(
        FC1_OUT, -1
    )

    out = {
        "w1_pm1": w1_pm1.astype(np.float32),
        "w1_packed": w1_packed.astype(np.uint32),
        "w2_packed": w2_packed.astype(np.uint32),
        "wfc1_packed": wfc1_packed.astype(np.uint32),
        "wfc2": np.asarray(params["wfc2"], np.float32),
        "bfc2": np.asarray(params["bfc2"], np.float32),
        "wfc3": np.asarray(params["wfc3"], np.float32),
        "bfc3": np.asarray(params["bfc3"], np.float32),
    }
    for i, name in ((1, "bn1"), (2, "bn2"), (3, "bn3")):
        theta, flip = ref.fold_bn_to_threshold(
            params[f"{name}_gamma"],
            params[f"{name}_beta"],
            state[f"{name}_mean"],
            state[f"{name}_var"],
            BN_EPS,
        )
        out[f"theta{i}"] = np.asarray(theta, np.float32)
        out[f"flip{i}"] = np.asarray(flip, np.uint32)
    if scheme in ("rgb", "gray"):
        out["input_t"] = np.asarray(params["input_t"], np.float32)
    return out


# ---------------------------------------------------------------------------
# BCNN inference — reference (jnp) and Pallas pipelines, single image
# ---------------------------------------------------------------------------


def _threshold_pm1(y, theta, flip):
    """counts -> +-1 floats via the folded threshold."""
    bits = ref.threshold_sign(y.astype(jnp.float32), theta, flip)
    return ref.bits_to_pm1(bits)


def _binarize_first(iw: dict, x, scheme: str):
    if scheme == "rgb":
        return binarize_input.threshold_rgb(x, jnp.asarray(iw["input_t"]))
    if scheme == "gray":
        return binarize_input.threshold_gray(x, jnp.asarray(iw["input_t"]))
    if scheme == "lbp":
        return binarize_input.lbp(x)
    raise ValueError(scheme)


def bcnn_infer_ref(iw: dict, x, scheme: str):
    """Reference inference.  x: (96,96,3) float -> logits (4,) f32.

    Pure jnp, bit-identical to the Pallas path (tested in
    tests/test_model.py).
    """
    c_in = binarize_input.input_channels(scheme)
    d1 = K * K * c_in
    if scheme == "none":
        w1 = jnp.asarray(iw["w1_pm1"])  # (32,5,5,3) +-1 floats
        y1 = ref.conv2d_float(x, w1)  # zero pad, float counts
    else:
        xb = _binarize_first(iw, x, scheme)
        y1 = ref.conv2d_packed(xb, jnp.asarray(iw["w1_pm1"]), 32).astype(jnp.float32)
    bits1 = ref.threshold_sign(y1, jnp.asarray(iw["theta1"]), jnp.asarray(iw["flip1"]))
    words1 = ref.pack_bits(bits1, 32)  # (96,96,1) channel-packed
    words1 = ref.orpool2x2_packed(words1)  # (48,48,1)

    # conv2 in the channel-packed domain: gather K*K words per pixel
    cols2 = _im2col_words_ref(words1, K)  # (2304, 25)
    counts2 = ref.packed_matmul(cols2, jnp.asarray(iw["w2_packed"]), K * K * CONV1_OUT)
    y2 = counts2.reshape(48, 48, CONV2_OUT).astype(jnp.float32)
    bits2 = ref.threshold_sign(y2, jnp.asarray(iw["theta2"]), jnp.asarray(iw["flip2"]))
    words2 = ref.orpool2x2_packed(ref.pack_bits(bits2, 32))  # (24,24,1)

    xfc = words2.reshape(-1)  # (576,) word order (y, x)
    y3 = ref.fc_packed(xfc, jnp.asarray(iw["wfc1_packed"]), 24 * 24 * CONV2_OUT)
    h3 = _threshold_pm1(y3, jnp.asarray(iw["theta3"]), jnp.asarray(iw["flip3"]))
    h4 = ref.sign_pm1(jnp.asarray(iw["wfc2"]) @ h3 + jnp.asarray(iw["bfc2"]))
    return jnp.asarray(iw["wfc3"]) @ h4 + jnp.asarray(iw["bfc3"])


def _im2col_words_ref(words, k: int):
    """'same' im2col over packed words, pad word = 0 (all channels -1)."""
    h, w, nw = words.shape
    r = (k - 1) // 2
    wp = jnp.pad(words, ((r, r), (r, r), (0, 0)))
    cols = []
    for dy in range(k):
        for dx in range(k):
            cols.append(wp[dy : dy + h, dx : dx + w, :])
    return jnp.stack(cols, axis=2).reshape(h * w, k * k * nw)


def bcnn_infer_pallas(iw: dict, x, scheme: str):
    """Pallas-kernel inference pipeline.  x: (96,96,3) -> logits (4,).

    This is the function AOT-lowered into the served artifact: every
    binarized stage runs through a Layer-1 kernel.
    """
    if scheme == "none":
        w1 = jnp.asarray(iw["w1_pm1"])
        cols = k_im2col.im2col_float(x, K)  # (9216, 75)
        y1 = k_bgemm.fgemm(cols, w1.reshape(CONV1_OUT, -1)).reshape(96, 96, CONV1_OUT)
    else:
        xb = _binarize_first(iw, x, scheme)
        c_in = xb.shape[-1]
        cols = k_im2col.im2col_pack(xb, K, 32)  # (9216, NW1)
        counts = k_bgemm.bgemm(cols, jnp.asarray(iw["w1_packed"]), K * K * c_in)
        y1 = counts.reshape(96, 96, CONV1_OUT).astype(jnp.float32)

    # threshold + channel-pack via the sign_pack kernel: bit = (z > 0)
    z1 = _threshold_z(y1, jnp.asarray(iw["theta1"]), jnp.asarray(iw["flip1"]))
    words1 = k_sign.sign_pack(z1.reshape(96 * 96, CONV1_OUT), 32).reshape(96, 96, 1)
    words1 = k_pool.orpool2x2(words1)  # (48,48,1)

    cols2 = _im2col_words_ref(words1, K)  # packed-word gather (cheap)
    counts2 = k_bgemm.bgemm(cols2, jnp.asarray(iw["w2_packed"]), K * K * CONV1_OUT)
    y2 = counts2.reshape(48, 48, CONV2_OUT).astype(jnp.float32)
    z2 = _threshold_z(y2, jnp.asarray(iw["theta2"]), jnp.asarray(iw["flip2"]))
    words2 = k_sign.sign_pack(z2.reshape(48 * 48, CONV2_OUT), 32).reshape(48, 48, 1)
    words2 = k_pool.orpool2x2(words2)  # (24,24,1)

    y3 = k_fc.fc_packed(
        words2.reshape(-1), jnp.asarray(iw["wfc1_packed"]), 24 * 24 * CONV2_OUT
    )
    h3 = _threshold_pm1(y3, jnp.asarray(iw["theta3"]), jnp.asarray(iw["flip3"]))
    h4 = ref.sign_pm1(jnp.asarray(iw["wfc2"]) @ h3 + jnp.asarray(iw["bfc2"]))
    return jnp.asarray(iw["wfc3"]) @ h4 + jnp.asarray(iw["bfc3"])


def _threshold_z(y, theta, flip):
    """Map counts to a float whose sign bit encodes the folded threshold:
    z > 0  iff  (y > theta) xor flip."""
    s = 1.0 - 2.0 * flip.astype(jnp.float32)
    return (y - theta) * s


# ---------------------------------------------------------------------------
# batched reference inference (for batching ablation artifacts)
# ---------------------------------------------------------------------------


def bcnn_infer_ref_batch(iw: dict, xs, scheme: str):
    """vmapped reference inference: xs (N,96,96,3) -> (N,4)."""
    return jax.vmap(lambda x: bcnn_infer_ref(iw, x, scheme))(xs)


# ---------------------------------------------------------------------------
# per-layer functions (Table 2 artifacts; weights are runtime arguments)
# ---------------------------------------------------------------------------


def layer_im2col_float(x, k: int = K):
    return ref.im2col(x, k, 0.0)


def layer_im2col_pack(x_pm1, k: int = K):
    return k_im2col.im2col_pack(x_pm1, k, 32)


def layer_gemm_float(cols, w2d):
    return cols @ w2d.T


def layer_bgemm(cols, wp, d_real: int):
    return k_bgemm.bgemm(cols, wp, d_real)


def layer_pool_float(x):
    return k_pool.maxpool2x2(x)


def layer_pool_or(words):
    return k_pool.orpool2x2(words)


def layer_fc_float(x, w):
    return w @ x


def layer_fc_packed(x_words, w_words, d_real: int):
    return k_fc.fc_packed(x_words, w_words, d_real)
