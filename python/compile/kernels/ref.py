"""Pure-jnp oracles for every Layer-1 kernel.

These definitions are the single source of truth for the bit-level
conventions; the Pallas kernels (this package) and the Rust engine
(``rust/src/bnn``) are both validated against them.

Conventions (shared with the paper where it specifies them):

* **sign** (Eq. 1): ``-1 if x <= 0 else +1``.
* **bit encoding**: +1 -> bit 1, -1 -> bit 0 (Eq. 2's ``(1+x)/2``).
* **packing** (Eq. 2): a length-D {0,1} row packs into ``ceil(D/B)``
  words; element ``i`` (0-based) lands in word ``i // B`` at bit position
  ``B-1 - (i % B)`` (MSB-first).  Tail bits beyond D are 0.  Words are
  stored as uint32 even for B < 32.
* **packed dot** (Eq. 4): ``a . b = D - 2 * sum_w popcount(xor(A_w, B_w))``
  with D the *real* (unpadded) length — valid because tail bits are 0 in
  both operands, contributing 0 to the xor-popcount.
* **binarized-conv padding**: the CUDA kernel zero-initializes shared
  memory and then takes ``s = sh_block[idx] > 0`` (Algorithm 1 line 8),
  so halo pixels become bit 0, i.e. **-1** in the xnor dot.  We adopt the
  same semantics: binarized convolutions pad with -1 (float convolutions
  pad with 0 as usual).
* **im2col patch order**: ``(dy, dx, c)`` flattened C-style, matching the
  row-major shared-memory walk of Algorithm 1.
"""

from __future__ import annotations

import jax.numpy as jnp
from jax import lax


# ---------------------------------------------------------------------------
# sign / bits
# ---------------------------------------------------------------------------


def sign_pm1(x):
    """Eq. (1): elementwise sign into {-1.0, +1.0} (sign(0) = -1)."""
    return jnp.where(x > 0, 1.0, -1.0).astype(jnp.float32)


def pm1_to_bits(x):
    """{-1,+1} (any numeric dtype) -> {0,1} uint32 (+1 -> 1)."""
    return (x > 0).astype(jnp.uint32)


def bits_to_pm1(b):
    """{0,1} -> {-1.0,+1.0} float32."""
    return jnp.where(b > 0, 1.0, -1.0).astype(jnp.float32)


# ---------------------------------------------------------------------------
# packing (Eq. 2)
# ---------------------------------------------------------------------------


def packed_width(d: int, b: int = 32) -> int:
    """Number of words a length-``d`` bit vector packs into."""
    return -(-d // b)


def pack_bits(bits, b: int = 32):
    """Pack {0,1} rows into words.  bits: (..., D) -> (..., ceil(D/B)) u32.

    Element i -> word i//B, bit position B-1-(i%B); tail bits are 0.
    """
    bits = bits.astype(jnp.uint32)
    d = bits.shape[-1]
    nw = packed_width(d, b)
    pad = nw * b - d
    if pad:
        bits = jnp.pad(bits, [(0, 0)] * (bits.ndim - 1) + [(0, pad)])
    grouped = bits.reshape(bits.shape[:-1] + (nw, b))
    shifts = jnp.arange(b - 1, -1, -1, dtype=jnp.uint32)
    return jnp.sum(grouped << shifts, axis=-1, dtype=jnp.uint32)


def unpack_bits(words, d: int, b: int = 32):
    """Inverse of :func:`pack_bits`: (..., NW) u32 -> (..., D) {0,1} u32."""
    shifts = jnp.arange(b - 1, -1, -1, dtype=jnp.uint32)
    bits = (words[..., None] >> shifts) & jnp.uint32(1)
    return bits.reshape(words.shape[:-1] + (-1,))[..., :d]


def packed_dot(a_words, b_words, d_real: int):
    """Eq. (4): xnor-popcount dot of two packed rows -> int32."""
    x = jnp.bitwise_xor(a_words, b_words)
    pc = jnp.sum(lax.population_count(x).astype(jnp.int32), axis=-1)
    return jnp.int32(d_real) - 2 * pc


def packed_matmul(a_words, w_words, d_real: int):
    """(M, NW) x (N, NW) packed -> (M, N) int32 counts (Eq. 4 GEMM)."""
    x = jnp.bitwise_xor(a_words[:, None, :], w_words[None, :, :])
    pc = jnp.sum(lax.population_count(x).astype(jnp.int32), axis=-1)
    return jnp.int32(d_real) - 2 * pc


# ---------------------------------------------------------------------------
# im2col (float and +-1 domains)
# ---------------------------------------------------------------------------


def im2col(x, k: int, pad_value: float = 0.0):
    """'same' im2col.  x: (H, W, C) -> (H*W, K*K*C), patch order (dy,dx,c).

    ``pad_value`` is 0 for float convs, -1 for binarized convs (see module
    docstring).
    """
    h, w, c = x.shape
    r = (k - 1) // 2
    xp = jnp.pad(x, ((r, r), (r, r), (0, 0)), constant_values=pad_value)
    cols = []
    for dy in range(k):
        for dx in range(k):
            cols.append(xp[dy : dy + h, dx : dx + w, :])
    # (H, W, K*K, C) -> (H*W, K*K*C) in (dy, dx, c) order
    patches = jnp.stack(cols, axis=2)
    return patches.reshape(h * w, k * k * c)


def im2col_pack(x_pm1, k: int, b: int = 32):
    """Fused patch-extraction + packing oracle (Algorithm 1 analog).

    x_pm1: (H, W, C) in {-1,+1}.  Returns (H*W, ceil(K*K*C/B)) u32.
    Padding pixels enter as bit 0 (= -1), matching the CUDA kernel.
    """
    patches = im2col(x_pm1, k, pad_value=-1.0)
    return pack_bits(pm1_to_bits(patches), b)


def conv2d_float(x, w):
    """Float 'same' conv via im2col+GEMM.  x: (H,W,C), w: (O,K,K,C)."""
    o, k, _, c = w.shape
    cols = im2col(x, k, 0.0)  # (H*W, K*K*C)
    wm = w.reshape(o, k * k * c)  # (dy,dx,c) order matches im2col
    return (cols @ wm.T).reshape(x.shape[0], x.shape[1], o)


def conv2d_pm1(x_pm1, w_pm1):
    """Binarized 'same' conv (pad = -1), exact integer counts as f32.

    Equals ``unpack(packed conv)``: every product is +-1, the sum over the
    K*K*C window is an integer in [-D, D] with D = K*K*C.
    """
    o, k, _, c = w_pm1.shape
    cols = im2col(x_pm1, k, -1.0)
    wm = w_pm1.reshape(o, k * k * c)
    return (cols @ wm.T).reshape(x_pm1.shape[0], x_pm1.shape[1], o)


def conv2d_packed(x_pm1, w_pm1, b: int = 32):
    """Binarized conv through the packed path (the kernel under test)."""
    o, k, _, c = w_pm1.shape
    d = k * k * c
    cols = im2col_pack(x_pm1, k, b)  # (H*W, NW)
    wp = pack_bits(pm1_to_bits(w_pm1.reshape(o, d)), b)  # (O, NW)
    counts = packed_matmul(cols, wp, d)  # (H*W, O) i32
    return counts.reshape(x_pm1.shape[0], x_pm1.shape[1], o)


# ---------------------------------------------------------------------------
# pooling
# ---------------------------------------------------------------------------


def maxpool2x2(x):
    """2x2/stride-2 max pool.  x: (H, W, C) with even H, W."""
    h, w, c = x.shape
    return jnp.max(x.reshape(h // 2, 2, w // 2, 2, c), axis=(1, 3))


def orpool2x2_packed(words):
    """2x2/stride-2 pool in the packed binary domain: bitwise OR.

    words: (H, W, NW) u32.  Valid because sign is monotone:
    ``sign(max(x)) = or(sign(x))`` bit-wise.
    """
    h, w, nw = words.shape
    g = words.reshape(h // 2, 2, w // 2, 2, nw)
    return g[:, 0, :, 0] | g[:, 0, :, 1] | g[:, 1, :, 0] | g[:, 1, :, 1]


# ---------------------------------------------------------------------------
# fully connected
# ---------------------------------------------------------------------------


def fc_float(x, w):
    """x: (D,), w: (L, D) -> (L,) float."""
    return w @ x


def fc_packed(x_words, w_words, d_real: int):
    """Packed FC (Section 3.2): per-row xnor-popcount dot -> (L,) i32."""
    return packed_dot(w_words, x_words[None, :], d_real)


# ---------------------------------------------------------------------------
# batch-norm threshold folding (inference)
# ---------------------------------------------------------------------------


def fold_bn_to_threshold(gamma, beta, mean, var, eps: float = 1e-5):
    """Fold BN + sign into an integer-count comparison.

    sign(gamma * (y - mean)/sqrt(var+eps) + beta) = +1
        iff  y > theta         (gamma > 0)
        iff  y < theta         (gamma < 0)
    with theta = mean - beta * sqrt(var+eps) / gamma.

    Returns (theta f32, flip u32) — flip=1 where gamma < 0.  gamma == 0
    collapses to the constant sign(beta); we encode that as theta = +-inf.
    """
    std = jnp.sqrt(var + eps)
    safe_gamma = jnp.where(gamma == 0, 1.0, gamma)
    theta = mean - beta * std / safe_gamma
    flip = (gamma < 0).astype(jnp.uint32)
    # gamma == 0: sign(beta) constant -> theta -inf (always fire) / +inf
    const_fire = jnp.where(beta > 0, -jnp.inf, jnp.inf)
    theta = jnp.where(gamma == 0, const_fire, theta)
    return theta.astype(jnp.float32), flip


def threshold_sign(y, theta, flip):
    """Apply a folded threshold: bits = (y > theta) xor flip."""
    gt = (y > theta).astype(jnp.uint32)
    return jnp.bitwise_xor(gt, flip)
