"""Pallas kernel: fused patch-extraction + packing (paper Algorithm 1).

The CUDA kernel assigns an ``S x W`` threadblock per image row-slab, loads
an ``(S+2R) x W`` region (with halo rows) into shared memory in three
steps, then each thread walks its K*K*C patch with an integer counter
(avoiding div/mod) and packs bits into a register word.

TPU adaptation (DESIGN.md §3): the grid walks row-slabs of ``S`` output
rows; the *pre-padded* image stays in (interpret-mode) ANY memory and the
kernel dynamic-slices its ``(S+2R, W+2R, C)`` slab — the BlockSpec analog
of the halo load (overlapping slabs cannot be expressed as disjoint
blocks).  Patch gathering is K*K static slices of the slab (vector loads,
no per-element index arithmetic), and packing is the same reshape +
shift-reduce as :mod:`sign_pack`.  Padding pixels enter as bit 0 (= -1),
exactly like the zero-initialized shared memory of the CUDA kernel.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref


def _im2col_pack_kernel(xp_ref, o_ref, *, s, h, w, c, k, nw, b):
    """Grid step i packs rows [i*S, i*S+S) of the output.

    xp_ref: full padded image (H+2R, W+2R, C), value domain {-1,+1} (pads
    are -1).  o_ref: (S*W, NW) u32 — packed patches for this slab.
    """
    i = pl.program_id(0)
    slab = xp_ref[pl.ds(i * s, s + k - 1), :, :]  # (S+2R, W+2R, C)
    cols = []
    for dy in range(k):
        for dx in range(k):
            cols.append(slab[dy : dy + s, dx : dx + w, :])  # (S, W, C)
    patches = jnp.stack(cols, axis=2).reshape(s * w, k * k * c)
    bits = (patches > 0).astype(jnp.uint32)
    d = k * k * c
    pad = nw * b - d
    if pad:
        bits = jnp.pad(bits, ((0, 0), (0, pad)))
    iota = jax.lax.broadcasted_iota(jnp.uint32, (b,), 0)
    shifts = jnp.uint32(b - 1) - iota
    grouped = bits.reshape(s * w, nw, b)
    o_ref[...] = jnp.sum(grouped << shifts, axis=-1, dtype=jnp.uint32)


@functools.partial(jax.jit, static_argnames=("k", "b", "s"))
def im2col_pack(x_pm1, k: int = 5, b: int = 32, s: int = 2):
    """Fused im2col+pack.  x_pm1: (H, W, C) {-1,+1} -> (H*W, NW) u32.

    ``s`` is the slab height (the paper's threadblock S = 2).  H must be
    divisible by ``s``.
    """
    h, w, c = x_pm1.shape
    assert h % s == 0, f"H={h} not divisible by slab height {s}"
    r = (k - 1) // 2
    nw = ref.packed_width(k * k * c, b)
    xp = jnp.pad(x_pm1, ((r, r), (r, r), (0, 0)), constant_values=-1.0)
    return pl.pallas_call(
        functools.partial(
            _im2col_pack_kernel, s=s, h=h, w=w, c=c, k=k, nw=nw, b=b
        ),
        grid=(h // s,),
        in_specs=[pl.BlockSpec(xp.shape, lambda i: (0, 0, 0))],
        out_specs=pl.BlockSpec((s * w, nw), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((h * w, nw), jnp.uint32),
        interpret=True,
    )(xp)


@functools.partial(jax.jit, static_argnames=("k",))
def im2col_float(x, k: int = 5):
    """Float im2col ('same', zero pad) — the full-precision baseline's
    explicit-GEMM lowering (paper: cuDNN explicit GEMM algorithm)."""
    return ref.im2col(x, k, 0.0)
