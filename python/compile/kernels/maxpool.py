"""Pallas kernels: 2x2/stride-2 max-pooling.

Two variants:

* :func:`maxpool2x2` — float domain, the layer the full-precision network
  and the paper's BCNN use (Table 2 rows "Max-Pooling").
* :func:`orpool2x2` — packed binary domain: since sign is monotone,
  ``sign(max(x)) == or(sign(x))`` bit-wise, so pooling after binarization
  is a bitwise OR of packed words — 32 channels pooled per instruction.
  This is our TPU adaptation (DESIGN.md §3, ablation E8).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _maxpool_kernel(x_ref, o_ref):
    """x_ref: (bh*2, W, C) -> o_ref: (bh, W/2, C)."""
    x = x_ref[...]
    h2, w, c = x.shape
    g = x.reshape(h2 // 2, 2, w // 2, 2, c)
    o_ref[...] = jnp.max(jnp.max(g, axis=3), axis=1)


@functools.partial(jax.jit, static_argnames=("block_rows",))
def maxpool2x2(x, block_rows: int = 8):
    """Float 2x2 max pool.  x: (H, W, C), H and W even -> (H/2, W/2, C)."""
    h, w, c = x.shape
    assert h % 2 == 0 and w % 2 == 0
    bh = min(block_rows, h // 2)
    assert (h // 2) % bh == 0
    return pl.pallas_call(
        _maxpool_kernel,
        grid=(h // 2 // bh,),
        in_specs=[pl.BlockSpec((bh * 2, w, c), lambda i: (i, 0, 0))],
        out_specs=pl.BlockSpec((bh, w // 2, c), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((h // 2, w // 2, c), x.dtype),
        interpret=True,
    )(x)


def _orpool_kernel(x_ref, o_ref):
    """x_ref: (bh*2, W, NW) u32 -> o_ref: (bh, W/2, NW) u32 (bitwise OR)."""
    x = x_ref[...]
    h2, w, nw = x.shape
    g = x.reshape(h2 // 2, 2, w // 2, 2, nw)
    o_ref[...] = g[:, 0, :, 0] | g[:, 0, :, 1] | g[:, 1, :, 0] | g[:, 1, :, 1]


@functools.partial(jax.jit, static_argnames=("block_rows",))
def orpool2x2(words, block_rows: int = 8):
    """Packed OR pool.  words: (H, W, NW) u32 -> (H/2, W/2, NW) u32."""
    h, w, nw = words.shape
    assert h % 2 == 0 and w % 2 == 0
    bh = min(block_rows, h // 2)
    assert (h // 2) % bh == 0
    return pl.pallas_call(
        _orpool_kernel,
        grid=(h // 2 // bh,),
        in_specs=[pl.BlockSpec((bh * 2, w, nw), lambda i: (i, 0, 0))],
        out_specs=pl.BlockSpec((bh, w // 2, nw), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((h // 2, w // 2, nw), jnp.uint32),
        interpret=True,
    )(words)
