"""Pallas kernel: fused sign + bit-packing (paper Eq. 1 + Eq. 2).

Maps rows of real values to packed words: bit = (x > 0), element ``i`` of
a row lands in word ``i // B`` at position ``B-1-(i % B)``.

TPU adaptation (DESIGN.md §3): the CUDA version packs in per-thread
registers with shifts inside Algorithm 1; here each grid step owns a row
tile resident in VMEM and packs with a reshape + weighted reduction
(``bits @ 2^shifts``), which the VPU vectorizes — no scalar loop, no
div/mod in the hot path (the reshape encodes ``i//B`` and ``i%B``).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref


def _sign_pack_kernel(x_ref, o_ref, *, nw: int, b: int):
    """One row-tile: x_ref (bm, NW*B) f32 -> o_ref (bm, NW) u32."""
    bits = (x_ref[...] > 0).astype(jnp.uint32)
    bm = bits.shape[0]
    grouped = bits.reshape(bm, nw, b)
    iota = jax.lax.broadcasted_iota(jnp.uint32, (b,), 0)
    shifts = jnp.uint32(b - 1) - iota
    o_ref[...] = jnp.sum(grouped << shifts, axis=-1, dtype=jnp.uint32)


@functools.partial(jax.jit, static_argnames=("b", "block_rows"))
def sign_pack(x, b: int = 32, block_rows: int = 128):
    """sign+pack rows.  x: (N, D) f32 -> (N, ceil(D/B)) u32.

    Elements past D (tail of the last word) pack as bit 0, matching
    :func:`ref.pack_bits` on ``ref.pm1_to_bits(ref.sign_pm1(x))``.
    """
    n, d = x.shape
    nw = ref.packed_width(d, b)
    dp = nw * b
    if dp != d:
        # tail elements must binarize to bit 0 => pad with a negative value
        x = jnp.pad(x, ((0, 0), (0, dp - d)), constant_values=-1.0)
    bm = min(block_rows, n)
    # pad N up to a tile multiple; extra rows are discarded after the call
    n_tiles = -(-n // bm)
    np_ = n_tiles * bm
    if np_ != n:
        x = jnp.pad(x, ((0, np_ - n), (0, 0)), constant_values=-1.0)
    out = pl.pallas_call(
        functools.partial(_sign_pack_kernel, nw=nw, b=b),
        grid=(n_tiles,),
        in_specs=[pl.BlockSpec((bm, dp), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((bm, nw), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((np_, nw), jnp.uint32),
        interpret=True,  # CPU PJRT cannot run Mosaic custom-calls
    )(x)
    return out[:n]
