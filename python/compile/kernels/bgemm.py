"""Pallas kernel: packed XNOR-popcount GEMM (paper Eq. 4 / Section 3.1).

Computes ``out[m, n] = D - 2 * sum_k popcount(xor(A[m, k], W[n, k]))``
over uint32 packed operands — the binarized replacement for the FMA GEMM
of explicit-GEMM convolution.

The CUDA version tiles both operands through shared memory (Tan et al.
DGEMM style), one output element per thread.  TPU adaptation (DESIGN.md
§3): the grid is (M-tiles x N-tiles); each step holds an (bm, KW) A-tile
and (bn, KW) W-tile in VMEM and forms the (bm, bn, KW) xor-popcount
reduction in vector registers.  On a real TPU the popcount lowering rides
the VPU (32-lane int ops); the MXU analog would require an int8 outer
product — see DESIGN.md §7 for the utilization estimate.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl


def _bgemm_kernel(a_ref, w_ref, o_ref, *, d_real: int):
    """o[m,n] = D - 2*popcount(xor) over the packed-K axis."""
    a = a_ref[...]  # (bm, KW) u32
    w = w_ref[...]  # (bn, KW) u32
    x = jnp.bitwise_xor(a[:, None, :], w[None, :, :])  # (bm, bn, KW)
    pc = jnp.sum(lax.population_count(x).astype(jnp.int32), axis=-1)
    o_ref[...] = jnp.int32(d_real) - 2 * pc


@functools.partial(jax.jit, static_argnames=("d_real", "bm", "bn"))
def bgemm(a_packed, w_packed, d_real: int, bm: int = 256, bn: int = 32):
    """Packed GEMM.  a: (M, KW) u32, w: (N, KW) u32 -> (M, N) i32.

    ``d_real`` is the true (pre-padding) bit length of the dot product;
    tail bits must be 0 in both operands (ref.py convention).
    """
    m, kw = a_packed.shape
    n, kw2 = w_packed.shape
    assert kw == kw2, f"packed widths differ: {kw} vs {kw2}"
    bm = min(bm, m)
    bn = min(bn, n)
    mt, nt = -(-m // bm), -(-n // bn)
    mp, np_ = mt * bm, nt * bn
    if mp != m:
        a_packed = jnp.pad(a_packed, ((0, mp - m), (0, 0)))
    if np_ != n:
        w_packed = jnp.pad(w_packed, ((0, np_ - n), (0, 0)))
    out = pl.pallas_call(
        functools.partial(_bgemm_kernel, d_real=d_real),
        grid=(mt, nt),
        in_specs=[
            pl.BlockSpec((bm, kw), lambda i, j: (i, 0)),
            pl.BlockSpec((bn, kw), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), jnp.int32),
        interpret=True,
    )(a_packed, w_packed)
    return out[:m, :n]


def _fgemm_kernel(a_ref, w_ref, o_ref):
    o_ref[...] = a_ref[...] @ w_ref[...].T


@functools.partial(jax.jit, static_argnames=("bm", "bn"))
def fgemm(a, w, bm: int = 256, bn: int = 32):
    """Float GEMM baseline with the same tiling.  (M,D)x(N,D) -> (M,N)."""
    m, d = a.shape
    n, _ = w.shape
    bm, bn = min(bm, m), min(bn, n)
    mt, nt = -(-m // bm), -(-n // bn)
    mp, np_ = mt * bm, nt * bn
    if mp != m:
        a = jnp.pad(a, ((0, mp - m), (0, 0)))
    if np_ != n:
        w = jnp.pad(w, ((0, np_ - n), (0, 0)))
    out = pl.pallas_call(
        _fgemm_kernel,
        grid=(mt, nt),
        in_specs=[
            pl.BlockSpec((bm, d), lambda i, j: (i, 0)),
            pl.BlockSpec((bn, d), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), jnp.float32),
        interpret=True,
    )(a, w)
    return out[:m, :n]
