"""Pallas kernel: packed fully-connected layer (paper Section 3.2).

The CUDA kernel splits each weight-row dot product into 64 segments, one
thread per segment, partial sums in shared memory, then a warp-level
reduction.  TPU adaptation (DESIGN.md §3): each grid step owns a tile of
output neurons; the packed-K axis is reshaped into (SEGMENTS, KW/SEGMENTS)
and reduced in two stages — the same associativity decomposition, but
expressed as vector reductions the VPU executes in lanes rather than
explicit thread cooperation.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl

SEGMENTS = 64  # paper's partial-sum segment count


def _fc_kernel(x_ref, w_ref, o_ref, *, d_real: int, segments: int):
    """x_ref: (1, KWp) u32, w_ref: (bl, KWp) u32 -> o_ref: (bl,) i32."""
    x = x_ref[...]
    w = w_ref[...]
    xr = jnp.bitwise_xor(w, x)  # (bl, KWp), broadcast row
    pc = lax.population_count(xr).astype(jnp.int32)
    bl, kwp = pc.shape
    # two-stage segmented reduction (paper's 64 partial sums + final sum)
    partial = jnp.sum(pc.reshape(bl, segments, kwp // segments), axis=-1)
    total = jnp.sum(partial, axis=-1)
    o_ref[...] = jnp.int32(d_real) - 2 * total


@functools.partial(jax.jit, static_argnames=("d_real", "block_rows", "segments"))
def fc_packed(x_words, w_words, d_real: int, block_rows: int = 32, segments: int = SEGMENTS):
    """Packed FC.  x: (KW,) u32, w: (L, KW) u32 -> (L,) i32 counts."""
    (kw,) = x_words.shape
    l, kw2 = w_words.shape
    assert kw == kw2
    # pad packed-K to a segment multiple (zero words xor as zero popcount)
    kwp = -(-kw // segments) * segments
    if kwp != kw:
        x_words = jnp.pad(x_words, (0, kwp - kw))
        w_words = jnp.pad(w_words, ((0, 0), (0, kwp - kw)))
    bl = min(block_rows, l)
    lt = -(-l // bl)
    lp = lt * bl
    if lp != l:
        w_words = jnp.pad(w_words, ((0, lp - l), (0, 0)))
    out = pl.pallas_call(
        functools.partial(_fc_kernel, d_real=d_real, segments=segments),
        grid=(lt,),
        in_specs=[
            pl.BlockSpec((1, kwp), lambda i: (0, 0)),
            pl.BlockSpec((bl, kwp), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((bl,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((lp,), jnp.int32),
        interpret=True,
    )(x_words[None, :], w_words)
    return out[:l]
