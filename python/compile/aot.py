"""AOT export: lower every model variant and per-layer function to HLO
**text** artifacts that the Rust runtime loads via PJRT.

Why text: jax >= 0.5 emits HloModuleProto with 64-bit instruction ids,
which xla_extension 0.5.1 (the version the published ``xla`` crate binds)
rejects; the text parser reassigns ids and round-trips cleanly.

Everything an executable needs at run time (weights included) is passed
as arguments — the Rust side uploads weight buffers once per model and
reuses them across requests (``execute_b``), so no multi-MB constants are
baked into the HLO text.

Outputs (``--out`` dir, default ../artifacts):
  manifest.json               index of everything below (Rust reads this)
  model_*.hlo.txt             end-to-end model variants
  layer_*.hlo.txt             per-layer functions (Table 2 benches)
  weights_float.bcnt          float network tensors
  weights_bcnn_<scheme>.bcnt  folded+packed BCNN tensors per scheme
  testset.bcnt                SynthVehicles test split (images + labels)
  expected_logits.bcnt        reference logits for cross-validation
"""

from __future__ import annotations

import argparse
import hashlib
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import binarize_input
from . import data as data_mod
from . import model as model_mod
from . import tensorio, train as train_mod
from .kernels import ref

SCHEMES = ("none", "rgb", "gray", "lbp")

#: canonical weight-argument order for BCNN artifacts (subset per scheme)
BCNN_ARGS = (
    "input_t",
    "w1_pm1",
    "w1_packed",
    "theta1",
    "flip1",
    "w2_packed",
    "theta2",
    "flip2",
    "wfc1_packed",
    "theta3",
    "flip3",
    "wfc2",
    "bfc2",
    "wfc3",
    "bfc3",
)
FLOAT_ARGS = ("w1", "b1", "w2", "b2", "wfc1", "bfc1", "wfc2", "bfc2", "wfc3", "bfc3")

_DTYPE_NAMES = {np.dtype(np.float32): "f32", np.dtype(np.int32): "i32", np.dtype(np.uint32): "u32"}


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (see module docstring).

    ``print_large_constants=True`` is load-bearing: the default printer
    elides big constant payloads as ``{...}``, which xla_extension
    0.5.1's text parser silently accepts as garbage — every downstream
    executable computes wrong numbers (caught by the Rust integration
    tests cross-checking against expected_logits.bcnt).
    """
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    opts = xc._xla.HloPrintOptions()
    opts.print_large_constants = True
    # modern metadata attributes (source_end_line etc.) are unknown to the
    # 0.5.1 parser — strip them
    opts.print_metadata = False
    return comp.as_hlo_module().to_string(opts)


def _spec(a):
    return jax.ShapeDtypeStruct(a.shape, a.dtype)


def _arg_meta(name, a):
    return {"name": name, "dtype": _DTYPE_NAMES[np.dtype(a.dtype)], "shape": list(a.shape)}


def _write(path, text):
    with open(path, "w") as f:
        f.write(text)


# ---------------------------------------------------------------------------
# weights: trained if available, deterministic random otherwise
# ---------------------------------------------------------------------------


def _get_float_params(out_dir, log):
    path = os.path.join(out_dir, "trained_float.bcnt")
    if os.path.exists(path):
        log(f"using trained float weights: {path}")
        params, _ = train_mod.load_params(path)
        return params, True
    log("no trained float weights — using seeded random init (perf-only)")
    return model_mod.init_float_params(jax.random.PRNGKey(7)), False


def _get_bcnn_params(out_dir, scheme, log):
    path = os.path.join(out_dir, f"trained_bcnn_{scheme}.bcnt")
    if os.path.exists(path):
        log(f"using trained bcnn/{scheme} weights: {path}")
        params, state = train_mod.load_params(path)
        return params, state, True
    log(f"no trained bcnn/{scheme} weights — using seeded random init (perf-only)")
    params = model_mod.init_bcnn_params(jax.random.PRNGKey(11), scheme)
    state = model_mod.init_bn_state()
    return params, state, False


# ---------------------------------------------------------------------------
# model artifacts
# ---------------------------------------------------------------------------


def export_float_models(out_dir, params, batches, manifest, log):
    weights = {k: np.asarray(params[k]) for k in FLOAT_ARGS}
    tensorio.save_tensors(os.path.join(out_dir, "weights_float.bcnt"), weights)
    for bs in batches:
        name = f"model_float_b{bs}"

        def fn(x, *ws):
            p = dict(zip(FLOAT_ARGS, ws))
            return (model_mod.float_forward(p, x),)

        x_spec = jax.ShapeDtypeStruct((bs, 96, 96, 3), jnp.float32)
        lowered = jax.jit(fn, keep_unused=True).lower(x_spec, *[_spec(weights[k]) for k in FLOAT_ARGS])
        _write(os.path.join(out_dir, f"{name}.hlo.txt"), to_hlo_text(lowered))
        manifest["models"].append(
            {
                "name": name,
                "file": f"{name}.hlo.txt",
                "kind": "float",
                "scheme": "float",
                "batch": bs,
                "weights_file": "weights_float.bcnt",
                "input": {"name": "x", "dtype": "f32", "shape": [bs, 96, 96, 3]},
                "weight_args": [_arg_meta(k, weights[k]) for k in FLOAT_ARGS],
                "output": {"dtype": "f32", "shape": [bs, 4]},
            }
        )
        log(f"  wrote {name}")


def export_bcnn_models(out_dir, scheme, iw, batches, manifest, log):
    args = [k for k in BCNN_ARGS if k in iw]
    wfile = f"weights_bcnn_{scheme}.bcnt"
    tensorio.save_tensors(os.path.join(out_dir, wfile), iw)

    # Pallas-kernel pipeline, single image (the served artifact)
    name = f"model_bcnn_{scheme}_b1"

    def fn_pallas(x, *ws):
        d = dict(zip(args, ws))
        return (model_mod.bcnn_infer_pallas(d, x, scheme),)

    x_spec = jax.ShapeDtypeStruct((96, 96, 3), jnp.float32)
    lowered = jax.jit(fn_pallas, keep_unused=True).lower(x_spec, *[_spec(iw[k]) for k in args])
    _write(os.path.join(out_dir, f"{name}.hlo.txt"), to_hlo_text(lowered))
    manifest["models"].append(
        {
            "name": name,
            "file": f"{name}.hlo.txt",
            "kind": "bcnn_pallas",
            "scheme": scheme,
            "batch": 1,
            "weights_file": wfile,
            "input": {"name": "x", "dtype": "f32", "shape": [96, 96, 3]},
            "weight_args": [_arg_meta(k, iw[k]) for k in args],
            "output": {"dtype": "f32", "shape": [4]},
        }
    )
    log(f"  wrote {name}")

    # Reference (pure-jnp packed) pipeline, batched — bit-identical logits
    for bs in batches:
        name = f"model_bcnn_{scheme}_ref_b{bs}"

        def fn_ref(xs, *ws):
            d = dict(zip(args, ws))
            return (model_mod.bcnn_infer_ref_batch(d, xs, scheme),)

        xs_spec = jax.ShapeDtypeStruct((bs, 96, 96, 3), jnp.float32)
        lowered = jax.jit(fn_ref, keep_unused=True).lower(xs_spec, *[_spec(iw[k]) for k in args])
        _write(os.path.join(out_dir, f"{name}.hlo.txt"), to_hlo_text(lowered))
        manifest["models"].append(
            {
                "name": name,
                "file": f"{name}.hlo.txt",
                "kind": "bcnn_ref",
                "scheme": scheme,
                "batch": bs,
                "weights_file": wfile,
                "input": {"name": "x", "dtype": "f32", "shape": [bs, 96, 96, 3]},
                "weight_args": [_arg_meta(k, iw[k]) for k in args],
                "output": {"dtype": "f32", "shape": [bs, 4]},
            }
        )
        log(f"  wrote {name}")


# ---------------------------------------------------------------------------
# per-layer artifacts (Table 2)
# ---------------------------------------------------------------------------


def export_layer_artifacts(out_dir, manifest, log):
    f32 = jnp.float32
    u32 = jnp.uint32
    S = jax.ShapeDtypeStruct
    layers = [
        # --- full-precision (explicit-GEMM lowering, as the paper's cuDNN) ---
        ("layer_im2col1_float", lambda x: (model_mod.layer_im2col_float(x),), [S((96, 96, 3), f32)]),
        ("layer_gemm1_float", lambda c, w: (model_mod.layer_gemm_float(c, w),), [S((9216, 75), f32), S((32, 75), f32)]),
        ("layer_pool1_float", lambda x: (model_mod.layer_pool_float(x),), [S((96, 96, 32), f32)]),
        ("layer_im2col2_float", lambda x: (model_mod.layer_im2col_float(x),), [S((48, 48, 32), f32)]),
        ("layer_gemm2_float", lambda c, w: (model_mod.layer_gemm_float(c, w),), [S((2304, 800), f32), S((32, 800), f32)]),
        ("layer_pool2_float", lambda x: (model_mod.layer_pool_float(x),), [S((48, 48, 32), f32)]),
        ("layer_fc_float", lambda x, w: (model_mod.layer_fc_float(x, w),), [S((18432,), f32), S((100, 18432), f32)]),
        # --- binarized (Pallas kernels) ---
        ("layer_im2col1_bin", lambda x: (model_mod.layer_im2col_pack(x),), [S((96, 96, 3), f32)]),
        ("layer_bgemm1", lambda c, w: (model_mod.layer_bgemm(c, w, 75),), [S((9216, 3), u32), S((32, 3), u32)]),
        ("layer_pool1_or", lambda x: (model_mod.layer_pool_or(x),), [S((96, 96, 1), u32)]),
        ("layer_im2col2_bin", lambda x: (model_mod.layer_im2col_pack(x),), [S((48, 48, 32), f32)]),
        ("layer_bgemm2", lambda c, w: (model_mod.layer_bgemm(c, w, 800),), [S((2304, 25), u32), S((32, 25), u32)]),
        ("layer_pool2_or", lambda x: (model_mod.layer_pool_or(x),), [S((48, 48, 1), u32)]),
        ("layer_fc_packed", lambda x, w: (model_mod.layer_fc_packed(x, w, 18432),), [S((576,), u32), S((100, 576), u32)]),
    ]
    for name, fn, specs in layers:
        lowered = jax.jit(fn).lower(*specs)
        _write(os.path.join(out_dir, f"{name}.hlo.txt"), to_hlo_text(lowered))
        manifest["layers"].append(
            {
                "name": name,
                "file": f"{name}.hlo.txt",
                "args": [
                    {"dtype": _DTYPE_NAMES[np.dtype(s.dtype)], "shape": list(s.shape)}
                    for s in specs
                ],
            }
        )
        log(f"  wrote {name}")


# ---------------------------------------------------------------------------
# test set + expected logits
# ---------------------------------------------------------------------------


def export_testset(out_dir, manifest, log, n_expected=8):
    (_, _), (x_test, y_test) = data_mod.load_splits(augment_train=False)
    tensorio.save_tensors(
        os.path.join(out_dir, "testset.bcnt"),
        {"images": x_test.astype(np.float32), "labels": y_test.astype(np.int32)},
    )
    manifest["testset"] = {"file": "testset.bcnt", "count": int(len(x_test))}
    log(f"  wrote testset.bcnt ({len(x_test)} images)")
    return x_test[:n_expected], y_test[:n_expected]


def export_expected_logits(out_dir, per_scheme_iw, float_params, x_head, manifest, log):
    """Reference logits on the first test images, for Rust cross-checks."""
    out = {"x": x_head.astype(np.float32)}
    logits = np.asarray(model_mod.float_forward(float_params, jnp.asarray(x_head)))
    out["logits_float"] = logits.astype(np.float32)
    for scheme, iw in per_scheme_iw.items():
        d = {k: jnp.asarray(v) for k, v in iw.items()}
        lg = np.asarray(model_mod.bcnn_infer_ref_batch(d, jnp.asarray(x_head), scheme))
        out[f"logits_bcnn_{scheme}"] = lg.astype(np.float32)
    tensorio.save_tensors(os.path.join(out_dir, "expected_logits.bcnt"), out)
    manifest["expected_logits"] = {"file": "expected_logits.bcnt", "count": int(len(x_head))}
    log("  wrote expected_logits.bcnt")


# ---------------------------------------------------------------------------


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--batches", default="1,4,16,64")
    ap.add_argument("--schemes", default=",".join(SCHEMES))
    ap.add_argument("--quiet", action="store_true")
    args = ap.parse_args()
    out_dir = args.out
    os.makedirs(out_dir, exist_ok=True)
    log = (lambda *a: None) if args.quiet else print
    batches = [int(b) for b in args.batches.split(",")]
    schemes = [s for s in args.schemes.split(",") if s]

    manifest = {
        "version": 1,
        "classes": list(data_mod.CLASSES),
        "models": [],
        "layers": [],
        "d_real": {"conv1": 75, "conv2": 800, "fc1": 18432},
        "trained": {},
    }

    log("=== float model ===")
    float_params, trained = _get_float_params(out_dir, log)
    manifest["trained"]["float"] = trained
    export_float_models(out_dir, float_params, batches, manifest, log)

    per_scheme_iw = {}
    for scheme in schemes:
        log(f"=== bcnn/{scheme} ===")
        params, state, trained = _get_bcnn_params(out_dir, scheme, log)
        manifest["trained"][scheme] = trained
        iw = model_mod.export_inference_weights(params, state, scheme)
        per_scheme_iw[scheme] = iw
        export_bcnn_models(out_dir, scheme, iw, batches, manifest, log)

    log("=== per-layer artifacts (Table 2) ===")
    export_layer_artifacts(out_dir, manifest, log)

    log("=== test set + expected logits ===")
    x_head, _ = export_testset(out_dir, manifest, log)
    export_expected_logits(out_dir, per_scheme_iw, float_params, x_head, manifest, log)

    if os.path.exists(os.path.join(out_dir, "table3.json")):
        manifest["table3"] = json.load(open(os.path.join(out_dir, "table3.json")))

    # content hash over the python sources, for make-style staleness checks
    h = hashlib.sha256()
    src_dir = os.path.dirname(os.path.abspath(__file__))
    for root, _, files in os.walk(src_dir):
        for f in sorted(files):
            if f.endswith(".py"):
                h.update(open(os.path.join(root, f), "rb").read())
    manifest["source_hash"] = h.hexdigest()[:16]

    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    log(f"manifest written: {len(manifest['models'])} models, {len(manifest['layers'])} layer kernels")


if __name__ == "__main__":
    main()
