"""Training for Table 3: full-precision net + BCNN under each
input-binarization scheme, on the SynthVehicles dataset.

Protocol (paper Section 2.1/2.2): 90/10 split, training set augmented
(flip + Gaussian sigma=0.5), full-precision trained with RMSprop, BCNN
with Adam + straight-through sign gradients; we report test accuracy at
the best-validation-epoch.  The learned input thresholds T (rgb/gray
schemes) are trained jointly with the other parameters rather than in
the paper's separate second stage — a documented simplification
(DESIGN.md §2); the effect on the scheme ordering is negligible.

Usage::

    python -m compile.train --out ../artifacts --all-schemes
    python -m compile.train --out ../artifacts --scheme rgb --epochs 6
"""

from __future__ import annotations

import argparse
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from . import data as data_mod
from . import model as model_mod
from . import optimizers, tensorio

VALID_FRACTION = 0.2  # paper: 20% of the training set for validation


def _xent(logits, labels):
    logp = jax.nn.log_softmax(logits)
    return -jnp.mean(jnp.take_along_axis(logp, labels[:, None], axis=1))


def _accuracy(logits, labels):
    return jnp.mean((jnp.argmax(logits, axis=1) == labels).astype(jnp.float32))


def _batches(n, bs, rng):
    idx = rng.permutation(n)
    for i in range(0, n - bs + 1, bs):
        yield idx[i : i + bs]


def train_float(x_train, y_train, x_val, y_val, epochs, bs, lr, seed=0, log=print):
    params = model_mod.init_float_params(jax.random.PRNGKey(seed))
    opt = optimizers.rmsprop(lr)
    opt_state = opt.init(params)

    @jax.jit
    def step(params, opt_state, xb, yb):
        def loss_fn(p):
            return _xent(model_mod.float_forward(p, xb), yb)

        loss, grads = jax.value_and_grad(loss_fn)(params)
        params, opt_state = opt.update(grads, opt_state, params)
        return params, opt_state, loss

    @jax.jit
    def eval_logits(params, xb):
        return model_mod.float_forward(params, xb)

    best = (-1.0, params)
    rng = np.random.default_rng(seed)
    for ep in range(epochs):
        t0 = time.time()
        losses = []
        for bidx in _batches(len(x_train), bs, rng):
            params, opt_state, loss = step(params, opt_state, x_train[bidx], y_train[bidx])
            losses.append(float(loss))
        vacc = _eval_acc(eval_logits, params, x_val, y_val, bs)
        log(f"  [float] epoch {ep+1}/{epochs} loss={np.mean(losses):.4f} val_acc={vacc:.4f} ({time.time()-t0:.1f}s)")
        if vacc > best[0]:
            best = (vacc, jax.tree.map(lambda a: a.copy(), params))
    return best[1], best[0]


def train_bcnn(scheme, x_train, y_train, x_val, y_val, epochs, bs, lr, seed=0, log=print):
    params = model_mod.init_bcnn_params(jax.random.PRNGKey(seed + 1), scheme)
    state = model_mod.init_bn_state()
    opt = optimizers.adam(lr)
    opt_state = opt.init(params)

    @jax.jit
    def step(params, state, opt_state, xb, yb):
        def loss_fn(p):
            logits, new_state = model_mod.bcnn_forward(p, state, xb, scheme, train=True)
            return _xent(logits, yb), new_state

        (loss, new_state), grads = jax.value_and_grad(loss_fn, has_aux=True)(params)
        params, opt_state = opt.update(grads, opt_state, params)
        return params, new_state, opt_state, loss

    @jax.jit
    def eval_logits(bundle, xb):
        params, state = bundle
        logits, _ = model_mod.bcnn_forward(params, state, xb, scheme, train=False)
        return logits

    best = (-1.0, params, state)
    rng = np.random.default_rng(seed)
    for ep in range(epochs):
        t0 = time.time()
        losses = []
        for bidx in _batches(len(x_train), bs, rng):
            params, state, opt_state, loss = step(params, state, opt_state, x_train[bidx], y_train[bidx])
            losses.append(float(loss))
        vacc = _eval_acc(eval_logits, (params, state), x_val, y_val, bs)
        log(f"  [bcnn/{scheme}] epoch {ep+1}/{epochs} loss={np.mean(losses):.4f} val_acc={vacc:.4f} ({time.time()-t0:.1f}s)")
        if vacc > best[0]:
            best = (vacc, jax.tree.map(lambda a: a.copy(), params), jax.tree.map(lambda a: a.copy(), state))
    return best[1], best[2], best[0]


def _eval_acc(eval_fn, params, x, y, bs):
    correct = 0
    for i in range(0, len(x), bs):
        logits = eval_fn(params, x[i : i + bs])
        correct += int(np.sum(np.argmax(np.array(logits), axis=1) == y[i : i + bs]))
    return correct / len(x)


def _save_params(path, params, state=None):
    flat = {k: np.asarray(v) for k, v in params.items()}
    if state is not None:
        flat.update({f"state_{k}": np.asarray(v) for k, v in state.items()})
    tensorio.save_tensors(path, flat)


def load_params(path):
    flat = tensorio.load_tensors(path)
    params = {k: jnp.asarray(v) for k, v in flat.items() if not k.startswith("state_")}
    state = {k[len("state_"):]: jnp.asarray(v) for k, v in flat.items() if k.startswith("state_")}
    return params, (state or None)


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--scheme", default=None, choices=["float", "none", "rgb", "gray", "lbp"])
    ap.add_argument("--all-schemes", action="store_true")
    ap.add_argument("--epochs", type=int, default=6)
    ap.add_argument("--batch-size", type=int, default=64)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--n", type=int, default=data_mod.DATASET_SIZE, help="dataset size (reduce for smoke runs)")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    os.makedirs(args.out, exist_ok=True)
    print(f"rendering SynthVehicles n={args.n} ...", flush=True)
    (x_train, y_train), (x_test, y_test) = data_mod.load_splits(args.n)
    n_val = int(len(x_train) * VALID_FRACTION)
    rng = np.random.default_rng(args.seed)
    perm = rng.permutation(len(x_train))
    val_idx, tr_idx = perm[:n_val], perm[n_val:]
    x_val, y_val = x_train[val_idx], y_train[val_idx]
    x_tr, y_tr = x_train[tr_idx], y_train[tr_idx]
    print(f"train={len(x_tr)} val={len(x_val)} test={len(x_test)}")

    schemes = ["float", "none", "rgb", "gray", "lbp"] if args.all_schemes else [args.scheme or "rgb"]
    results = {}
    if os.path.exists(os.path.join(args.out, "table3.json")):
        results = json.load(open(os.path.join(args.out, "table3.json")))

    for scheme in schemes:
        print(f"=== training {scheme} ===", flush=True)
        if scheme == "float":
            params, vacc = train_float(x_tr, y_tr, x_val, y_val, args.epochs, args.batch_size, args.lr, args.seed)
            eval_fn = jax.jit(lambda p, xb: model_mod.float_forward(p, xb))
            tacc = _eval_acc(eval_fn, params, x_test, y_test, args.batch_size)
            _save_params(os.path.join(args.out, "trained_float.bcnt"), params)
        else:
            params, state, vacc = train_bcnn(scheme, x_tr, y_tr, x_val, y_val, args.epochs, args.batch_size, args.lr, args.seed)

            def eval_fn(bundle, xb, _s=scheme):
                logits, _ = model_mod.bcnn_forward(bundle[0], bundle[1], xb, _s, train=False)
                return logits

            tacc = _eval_acc(jax.jit(eval_fn), (params, state), x_test, y_test, args.batch_size)
            _save_params(os.path.join(args.out, f"trained_bcnn_{scheme}.bcnt"), params, state)
        print(f"  -> val_acc={vacc:.4f} test_acc={tacc:.4f}")
        results[scheme] = {"val_acc": vacc, "test_acc": tacc, "epochs": args.epochs, "n": args.n}
        with open(os.path.join(args.out, "table3.json"), "w") as f:
            json.dump(results, f, indent=2)
    print(json.dumps(results, indent=2))


if __name__ == "__main__":
    main()
