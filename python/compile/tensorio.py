"""Tiny named-tensor container shared between the Python build path and the
Rust runtime (``rust/src/util/tensorio.rs`` implements the reader).

Layout (little-endian throughout)::

    magic   : 4 bytes  b"BCNT"
    version : u32      (currently 1)
    count   : u32      number of tensors
    repeat count times:
        name_len : u32
        name     : name_len bytes (utf-8)
        dtype    : u32   0=f32, 1=i32, 2=u32, 3=u8, 4=i8
        ndim     : u32
        dims     : ndim * u64
        payload  : prod(dims) * sizeof(dtype) bytes, C order

No compression, no alignment games — the files are small (a few MB) and
the format must be trivially re-implementable in Rust without serde.
"""

from __future__ import annotations

import struct

import numpy as np

MAGIC = b"BCNT"
VERSION = 1

_DTYPES = {
    np.dtype(np.float32): 0,
    np.dtype(np.int32): 1,
    np.dtype(np.uint32): 2,
    np.dtype(np.uint8): 3,
    np.dtype(np.int8): 4,
}
_RDTYPES = {v: k for k, v in _DTYPES.items()}


def save_tensors(path: str, tensors: dict[str, np.ndarray]) -> None:
    """Write ``tensors`` (name -> array) to ``path`` in BCNT format."""
    with open(path, "wb") as f:
        f.write(MAGIC)
        f.write(struct.pack("<II", VERSION, len(tensors)))
        for name, arr in tensors.items():
            arr = np.ascontiguousarray(arr)
            if arr.dtype not in _DTYPES:
                raise TypeError(f"unsupported dtype {arr.dtype} for tensor {name!r}")
            nb = name.encode("utf-8")
            f.write(struct.pack("<I", len(nb)))
            f.write(nb)
            f.write(struct.pack("<II", _DTYPES[arr.dtype], arr.ndim))
            for d in arr.shape:
                f.write(struct.pack("<Q", d))
            f.write(arr.tobytes())


def load_tensors(path: str) -> dict[str, np.ndarray]:
    """Read a BCNT file back into a dict of arrays."""
    out: dict[str, np.ndarray] = {}
    with open(path, "rb") as f:
        if f.read(4) != MAGIC:
            raise ValueError(f"{path}: bad magic")
        version, count = struct.unpack("<II", f.read(8))
        if version != VERSION:
            raise ValueError(f"{path}: unsupported version {version}")
        for _ in range(count):
            (name_len,) = struct.unpack("<I", f.read(4))
            name = f.read(name_len).decode("utf-8")
            dtype_code, ndim = struct.unpack("<II", f.read(8))
            dims = struct.unpack(f"<{ndim}Q", f.read(8 * ndim)) if ndim else ()
            dtype = _RDTYPES[dtype_code]
            n = int(np.prod(dims)) if ndim else 1
            arr = np.frombuffer(f.read(n * dtype.itemsize), dtype=dtype)
            out[name] = arr.reshape(dims).copy()
    return out
