"""Hand-rolled optimizers (the image has no optax).

The paper trains the full-precision network with RMSprop [23] and the
binarized network with Adam [15]; both are implemented here as simple
pytree transforms: ``init(params) -> state`` and
``update(grads, state, params) -> (new_params, new_state)``.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Callable

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class Optimizer:
    init: Callable
    update: Callable


def adam(lr: float = 1e-3, b1: float = 0.9, b2: float = 0.999, eps: float = 1e-8) -> Optimizer:
    """Adam (Kingma & Ba) — used for the BCNN."""

    def init(params):
        zeros = jax.tree.map(jnp.zeros_like, params)
        return {"m": zeros, "v": jax.tree.map(jnp.zeros_like, params), "t": jnp.zeros((), jnp.int32)}

    def update(grads, state, params):
        t = state["t"] + 1
        m = jax.tree.map(lambda m_, g: b1 * m_ + (1 - b1) * g, state["m"], grads)
        v = jax.tree.map(lambda v_, g: b2 * v_ + (1 - b2) * g * g, state["v"], grads)
        tf = t.astype(jnp.float32)
        bc1 = 1 - b1**tf
        bc2 = 1 - b2**tf
        new_params = jax.tree.map(
            lambda p, m_, v_: p - lr * (m_ / bc1) / (jnp.sqrt(v_ / bc2) + eps),
            params,
            m,
            v,
        )
        return new_params, {"m": m, "v": v, "t": t}

    return Optimizer(init, update)


def rmsprop(lr: float = 1e-3, decay: float = 0.9, eps: float = 1e-8) -> Optimizer:
    """RMSprop (Tieleman & Hinton) — used for the full-precision net."""

    def init(params):
        return {"s": jax.tree.map(jnp.zeros_like, params)}

    def update(grads, state, params):
        s = jax.tree.map(lambda s_, g: decay * s_ + (1 - decay) * g * g, state["s"], grads)
        new_params = jax.tree.map(
            lambda p, s_, g: p - lr * g / (jnp.sqrt(s_) + eps), params, s, grads
        )
        return new_params, {"s": s}

    return Optimizer(init, update)
