"""Input binarization schemes from the paper (Section 2.3).

All three map a float image in [0, 1] to a {-1, +1} image that the first
binarized conv layer consumes:

* ``threshold_rgb``   — sign(X + T) with a learned per-channel threshold
                        T in R^{1x1x3} (paper's chosen scheme: 92.52%).
* ``threshold_gray``  — grayscale then sign(gray + t), single channel
                        broadcast to one binary channel (89.16%).
* ``lbp``             — modified local binary patterns: radius-1
                        neighbourhood of the grayscale image, 3 of the 8
                        neighbours selected at clockwise stride 3, each
                        becoming one binary channel; bit = neighbour >
                        center (92.06%).

These are written in pure jnp so they lower into the same HLO module as
the model (their cost is part of the serving path, as in the paper).
"""

from __future__ import annotations

import jax.numpy as jnp

#: Luma weights for grayscale conversion (ITU-R BT.601).
_LUMA = jnp.array([0.299, 0.587, 0.114], dtype=jnp.float32)


def sign_pm1(x):
    """Paper Eq. (1): -1 if x <= 0 else +1 (note: sign(0) = -1)."""
    return jnp.where(x > 0, 1.0, -1.0).astype(x.dtype)


def threshold_rgb(x, t):
    """sign(X + T), T per channel.  x: (..., H, W, 3), t: (3,)."""
    return sign_pm1(x + t.reshape((1,) * (x.ndim - 1) + (3,)))


def threshold_gray(x, t):
    """Grayscale threshold: sign(luma(X) + t).  Output (..., H, W, 1)."""
    gray = jnp.tensordot(x, _LUMA, axes=([-1], [0]))
    return sign_pm1(gray + t)[..., None]


# Neighbour offsets at radius 1, clockwise from the top-left corner:
#   (-1,-1) (-1,0) (-1,+1) (0,+1) (+1,+1) (+1,0) (+1,-1) (0,-1)
_NEIGHBOURS = (
    (-1, -1), (-1, 0), (-1, 1), (0, 1), (1, 1), (1, 0), (1, -1), (0, -1),
)
#: Paper: "select 3 pixels at a clockwise stride of 3 in the neighbourhood"
_LBP_SELECT = (0, 3, 6)


def lbp(x):
    """Modified LBP input binarization (paper Section 2.3).

    x: (..., H, W, 3) float in [0,1].  Returns (..., H, W, 3) in {-1,+1}:
    channel k is +1 where neighbour ``_LBP_SELECT[k]`` exceeds the center
    pixel of the grayscale image, -1 otherwise.  Edges use zero padding
    (border neighbours read 0, matching the CUDA kernel's halo init).
    """
    gray = jnp.tensordot(x, _LUMA, axes=([-1], [0]))  # (..., H, W)
    padded = jnp.pad(gray, [(0, 0)] * (gray.ndim - 2) + [(1, 1), (1, 1)])
    h, w = gray.shape[-2], gray.shape[-1]
    chans = []
    for k in _LBP_SELECT:
        dy, dx = _NEIGHBOURS[k]
        neigh = padded[..., 1 + dy : 1 + dy + h, 1 + dx : 1 + dx + w]
        chans.append(jnp.where(neigh > gray, 1.0, -1.0))
    return jnp.stack(chans, axis=-1).astype(x.dtype)


SCHEMES = ("none", "rgb", "gray", "lbp")


def apply_scheme(scheme: str, x, params):
    """Dispatch: returns (binarized-or-raw input, #channels seen by conv1).

    ``params`` is the model parameter dict (thresholds live there so they
    can be trained; see train.py).
    """
    if scheme == "none":
        return x, x.shape[-1]
    if scheme == "rgb":
        return threshold_rgb(x, params["input_t"]), 3
    if scheme == "gray":
        return threshold_gray(x, params["input_t"]), 1
    if scheme == "lbp":
        return lbp(x), 3
    raise ValueError(f"unknown input-binarization scheme {scheme!r}")


def input_channels(scheme: str) -> int:
    """Number of channels conv1 sees under ``scheme``."""
    return {"none": 3, "rgb": 3, "gray": 1, "lbp": 3}[scheme]
