"""Model-level invariants: ref vs Pallas inference equality, BN folding,
export shapes, training-graph vs folded-inference agreement."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import data as data_mod
from compile import model as M
from compile.kernels import ref

SCHEMES = ("none", "rgb", "gray", "lbp")


def _random_net(scheme, seed=0, perturb_state=True):
    params = M.init_bcnn_params(jax.random.PRNGKey(seed), scheme)
    state = M.init_bn_state()
    if perturb_state:
        state = {
            k: (v + 0.37 if "mean" in k else v * 1.9 + 0.1) for k, v in state.items()
        }
    return params, state


@pytest.fixture(scope="module")
def image():
    return jnp.asarray(data_mod.render_vehicle(3).image)


@pytest.mark.parametrize("scheme", SCHEMES)
def test_ref_equals_pallas_inference(scheme, image):
    params, state = _random_net(scheme)
    iw = M.export_inference_weights(params, state, scheme)
    iwj = {k: jnp.asarray(v) for k, v in iw.items()}
    a = np.asarray(M.bcnn_infer_ref(iwj, image, scheme))
    b = np.asarray(M.bcnn_infer_pallas(iwj, image, scheme))
    np.testing.assert_array_equal(a, b)


@pytest.mark.parametrize("scheme", SCHEMES)
def test_batched_ref_matches_single(scheme):
    params, state = _random_net(scheme, seed=4)
    iw = {k: jnp.asarray(v) for k, v in M.export_inference_weights(params, state, scheme).items()}
    xs = jnp.asarray(np.stack([data_mod.render_vehicle(i).image for i in range(3)]))
    batched = np.asarray(M.bcnn_infer_ref_batch(iw, xs, scheme))
    for i in range(3):
        single = np.asarray(M.bcnn_infer_ref(iw, xs[i], scheme))
        # the binarized pipeline is bit-identical; the float fc tail may
        # round differently under vmap (batched matmul association)
        np.testing.assert_allclose(batched[i], single, atol=1e-5, rtol=1e-5)
        assert int(np.argmax(batched[i])) == int(np.argmax(single))


@pytest.mark.parametrize("scheme", SCHEMES)
def test_train_graph_agrees_with_folded_inference(scheme, image):
    # eval-mode training graph and the folded/packed inference pipeline
    # implement the same function (up to sign-boundary float ties, which
    # the random init makes measure-zero)
    params, state = _random_net(scheme, seed=9)
    logits_train, _ = M.bcnn_forward(params, state, image[None], scheme, train=False)
    iw = {k: jnp.asarray(v) for k, v in M.export_inference_weights(params, state, scheme).items()}
    logits_inf = M.bcnn_infer_ref(iw, image, scheme)
    assert int(jnp.argmax(logits_train[0])) == int(jnp.argmax(logits_inf))
    np.testing.assert_allclose(np.asarray(logits_train)[0], np.asarray(logits_inf), atol=1e-4)


def test_export_shapes_rgb():
    params, state = _random_net("rgb")
    iw = M.export_inference_weights(params, state, "rgb")
    assert iw["w1_packed"].shape == (32, 3)  # ceil(75/32)
    assert iw["w2_packed"].shape == (32, 25)
    assert iw["wfc1_packed"].shape == (100, 576)
    assert iw["theta1"].shape == (32,)
    assert iw["input_t"].shape == (3,)


def test_export_shapes_gray():
    params, state = _random_net("gray")
    iw = M.export_inference_weights(params, state, "gray")
    assert iw["w1_packed"].shape == (32, 1)  # ceil(25/32)
    assert iw["input_t"].shape == (1,)


def test_bn_fold_threshold_semantics():
    gamma = jnp.array([2.0, -1.5, 0.0, 0.0])
    beta = jnp.array([1.0, 0.5, 3.0, -2.0])
    mean = jnp.array([10.0, -4.0, 0.0, 0.0])
    var = jnp.array([4.0, 1.0, 1.0, 1.0])
    theta, flip = ref.fold_bn_to_threshold(gamma, beta, mean, var, eps=0.0)
    y = jnp.array([[12.0, -3.0, 123.0, -123.0]])
    bits = np.asarray(ref.threshold_sign(y, theta, flip))[0]
    # direct check: sign(gamma*(y-mean)/std + beta) > 0
    direct = (gamma * (y[0] - mean) / jnp.sqrt(var) + beta) > 0
    np.testing.assert_array_equal(bits.astype(bool), np.asarray(direct))


def test_bn_fold_random_agreement():
    rng = np.random.default_rng(0)
    for _ in range(50):
        gamma = rng.standard_normal(8).astype(np.float32)
        beta = rng.standard_normal(8).astype(np.float32)
        mean = rng.standard_normal(8).astype(np.float32) * 10
        var = rng.random(8).astype(np.float32) + 0.1
        y = rng.standard_normal((5, 8)).astype(np.float32) * 20
        theta, flip = ref.fold_bn_to_threshold(
            jnp.asarray(gamma), jnp.asarray(beta), jnp.asarray(mean), jnp.asarray(var), eps=0.0
        )
        bits = np.asarray(ref.threshold_sign(jnp.asarray(y), theta, flip))
        z = gamma * (y - mean) / np.sqrt(var) + beta
        np.testing.assert_array_equal(bits, (z > 0).astype(np.uint32))


def test_float_forward_shapes_and_finiteness():
    params = M.init_float_params(jax.random.PRNGKey(1))
    xs = jnp.asarray(np.stack([data_mod.render_vehicle(i).image for i in range(2)]))
    logits = M.float_forward(params, xs)
    assert logits.shape == (2, 4)
    assert bool(jnp.isfinite(logits).all())


def test_ste_sign_gradient_is_identity():
    g = jax.grad(lambda x: M.ste_sign(x).sum())(jnp.array([-2.0, 0.5, 3.0]))
    np.testing.assert_array_equal(np.asarray(g), [1.0, 1.0, 1.0])


def test_ste_sign_clip_gradient_masks_saturated():
    g = jax.grad(lambda x: M.ste_sign_clip(x).sum())(jnp.array([-2.0, 0.5, 3.0]))
    np.testing.assert_array_equal(np.asarray(g), [0.0, 1.0, 0.0])


def test_training_step_reduces_loss_smoke():
    # tiny BCNN training smoke test: loss decreases on a fixed batch
    from compile import optimizers

    scheme = "rgb"
    params, state = _random_net(scheme, perturb_state=False)
    opt = optimizers.adam(1e-2)
    opt_state = opt.init(params)
    xs = jnp.asarray(np.stack([data_mod.render_vehicle(i).image for i in range(16)]))
    ys = jnp.asarray(np.array([i % 4 for i in range(16)], dtype=np.int32))

    def loss_fn(p, s):
        logits, ns = M.bcnn_forward(p, s, xs, scheme, train=True)
        logp = jax.nn.log_softmax(logits)
        return -jnp.mean(jnp.take_along_axis(logp, ys[:, None], axis=1)), ns

    @jax.jit
    def step(p, s, o):
        (loss, ns), grads = jax.value_and_grad(loss_fn, has_aux=True)(p, s)
        p, o = opt.update(grads, o, p)
        return p, ns, o, loss

    losses = []
    for _ in range(8):
        params, state, opt_state, loss = step(params, state, opt_state)
        losses.append(float(loss))
    assert losses[-1] < losses[0], f"loss did not decrease: {losses}"
