"""BCNT container round-trips + the exact byte layout Rust parses."""

import struct

import numpy as np
import pytest

from compile import tensorio


def test_roundtrip(tmp_path):
    path = str(tmp_path / "t.bcnt")
    tensors = {
        "a": np.arange(12, dtype=np.float32).reshape(3, 4),
        "b": np.array([0, 1, 2**32 - 1], dtype=np.uint32),
        "c": np.array([-5], dtype=np.int32),
        "d": np.array(3.5, dtype=np.float32),  # scalar
    }
    tensorio.save_tensors(path, tensors)
    out = tensorio.load_tensors(path)
    assert list(out.keys()) == list(tensors.keys())
    for k in tensors:
        np.testing.assert_array_equal(out[k], tensors[k])
        assert out[k].dtype == tensors[k].dtype


def test_byte_layout_is_stable(tmp_path):
    # Freeze the exact header layout the Rust reader implements.
    path = str(tmp_path / "l.bcnt")
    tensorio.save_tensors(path, {"ab": np.array([7], dtype=np.uint32)})
    raw = open(path, "rb").read()
    assert raw[:4] == b"BCNT"
    version, count = struct.unpack("<II", raw[4:12])
    assert (version, count) == (1, 1)
    (name_len,) = struct.unpack("<I", raw[12:16])
    assert name_len == 2
    assert raw[16:18] == b"ab"
    dtype_code, ndim = struct.unpack("<II", raw[18:26])
    assert (dtype_code, ndim) == (2, 1)  # u32, 1-d
    (dim0,) = struct.unpack("<Q", raw[26:34])
    assert dim0 == 1
    (value,) = struct.unpack("<I", raw[34:38])
    assert value == 7


def test_rejects_bad_magic(tmp_path):
    path = tmp_path / "bad.bcnt"
    path.write_bytes(b"XXXX" + b"\x00" * 16)
    with pytest.raises(ValueError):
        tensorio.load_tensors(str(path))


def test_rejects_unsupported_dtype(tmp_path):
    with pytest.raises(TypeError):
        tensorio.save_tensors(
            str(tmp_path / "f64.bcnt"), {"x": np.array([1.0], dtype=np.float64)}
        )
