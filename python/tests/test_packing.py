"""Bit-level invariants of the packing scheme (paper Eq. 2 + Eq. 4),
property-tested against brute-force references with deterministic seeded
sweeps (the offline image carries no hypothesis)."""

import itertools

import jax.numpy as jnp
import numpy as np
import pytest

from compile.kernels import ref


def _cases_bits_and_width(n_cases=80, max_d=512):
    """Deterministic (bits, b) cases mirroring the old composite strategy."""
    rng = np.random.default_rng(0xBC44)
    widths = [1, 7, 8, 16, 25, 31, 32]
    for i in range(n_cases):
        d = int(rng.integers(1, max_d + 1))
        b = widths[i % len(widths)]
        bits = rng.integers(0, 2, d).astype(np.uint32)
        yield bits, b


@pytest.mark.parametrize("case", list(_cases_bits_and_width()), ids=lambda c: f"d{len(c[0])}b{c[1]}")
def test_unpack_inverts_pack(case):
    bits, b = case
    packed = ref.pack_bits(jnp.asarray(bits), b)
    assert packed.shape[-1] == ref.packed_width(len(bits), b)
    got = np.asarray(ref.unpack_bits(packed, len(bits), b))
    np.testing.assert_array_equal(got, bits)


@pytest.mark.parametrize(
    "d,b,seed",
    [
        (d, b, seed)
        for (d, seed), b in itertools.product(
            [(1, 0), (31, 1), (32, 2), (33, 3), (257, 4), (400, 5)],
            [8, 16, 25, 32],
        )
    ],
)
def test_packed_dot_matches_pm1_dot(d, b, seed):
    rng = np.random.default_rng(seed * 331 + d)
    xa = rng.integers(0, 2, d).astype(np.uint32)
    xb = rng.integers(0, 2, d).astype(np.uint32)
    pa = ref.pack_bits(jnp.asarray(xa), b)
    pb = ref.pack_bits(jnp.asarray(xb), b)
    got = int(ref.packed_dot(pa, pb, d))
    want = int(np.sum((xa.astype(np.int64) * 2 - 1) * (xb.astype(np.int64) * 2 - 1)))
    assert got == want


@pytest.mark.parametrize("seed", range(40))
def test_packed_dot_bounds_and_parity(seed):
    rng = np.random.default_rng(seed)
    d = int(rng.integers(1, 201))
    pa = ref.pack_bits(jnp.asarray(rng.integers(0, 2, d).astype(np.uint32)), 32)
    pb = ref.pack_bits(jnp.asarray(rng.integers(0, 2, d).astype(np.uint32)), 32)
    dot = int(ref.packed_dot(pa, pb, d))
    assert abs(dot) <= d
    assert (dot + d) % 2 == 0


def test_eq2_example_msb_first():
    # element 0 occupies the highest bit of the word
    w = np.asarray(ref.pack_bits(jnp.array([[1, 0, 1, 1]], dtype=jnp.uint32), 4))
    assert w.tolist() == [[0b1011]]
    w = np.asarray(ref.pack_bits(jnp.array([[1, 0, 0]], dtype=jnp.uint32), 32))
    assert w.tolist() == [[0b100 << 29]]


def test_tail_bits_are_zero():
    w = np.asarray(ref.pack_bits(jnp.ones((1, 3), dtype=jnp.uint32), 32))
    assert w[0, 0] == 0b111 << 29


def test_sign_of_zero_is_minus_one():
    out = np.asarray(ref.sign_pm1(jnp.array([-1.0, 0.0, 1e-9, 2.0])))
    np.testing.assert_array_equal(out, [-1.0, -1.0, 1.0, 1.0])


@pytest.mark.parametrize(
    "d,n,seed",
    [(d, n, seed) for (d, n), seed in itertools.product([(1, 1), (17, 3), (64, 6)], range(5))],
)
def test_packed_matmul_matches_rowwise_dot(d, n, seed):
    rng = np.random.default_rng(seed * 131 + d)
    a = rng.integers(0, 2, (5, d)).astype(np.uint32)
    w = rng.integers(0, 2, (n, d)).astype(np.uint32)
    pa = ref.pack_bits(jnp.asarray(a), 32)
    pw = ref.pack_bits(jnp.asarray(w), 32)
    got = np.asarray(ref.packed_matmul(pa, pw, d))
    for i in range(5):
        for j in range(n):
            want = int(ref.packed_dot(pa[i], pw[j], d))
            assert got[i, j] == want
