"""Bit-level invariants of the packing scheme (paper Eq. 2 + Eq. 4),
property-tested with hypothesis against brute-force references."""

import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings
from hypothesis import strategies as st

from compile.kernels import ref


@st.composite
def bits_and_width(draw, max_d=512):
    d = draw(st.integers(1, max_d))
    b = draw(st.sampled_from([1, 7, 8, 16, 25, 31, 32]))
    bits = draw(st.lists(st.integers(0, 1), min_size=d, max_size=d))
    return np.array(bits, dtype=np.uint32), b


@settings(max_examples=80, deadline=None)
@given(bits_and_width())
def test_unpack_inverts_pack(case):
    bits, b = case
    packed = ref.pack_bits(jnp.asarray(bits), b)
    assert packed.shape[-1] == ref.packed_width(len(bits), b)
    got = np.asarray(ref.unpack_bits(packed, len(bits), b))
    np.testing.assert_array_equal(got, bits)


@settings(max_examples=80, deadline=None)
@given(st.integers(1, 400), st.sampled_from([8, 16, 25, 32]), st.integers(0, 2**32 - 1))
def test_packed_dot_matches_pm1_dot(d, b, seed):
    rng = np.random.default_rng(seed)
    xa = rng.integers(0, 2, d).astype(np.uint32)
    xb = rng.integers(0, 2, d).astype(np.uint32)
    pa = ref.pack_bits(jnp.asarray(xa), b)
    pb = ref.pack_bits(jnp.asarray(xb), b)
    got = int(ref.packed_dot(pa, pb, d))
    want = int(np.sum((xa.astype(np.int64) * 2 - 1) * (xb.astype(np.int64) * 2 - 1)))
    assert got == want


@settings(max_examples=40, deadline=None)
@given(st.integers(1, 200), st.integers(0, 2**32 - 1))
def test_packed_dot_bounds_and_parity(d, seed):
    rng = np.random.default_rng(seed)
    pa = ref.pack_bits(jnp.asarray(rng.integers(0, 2, d).astype(np.uint32)), 32)
    pb = ref.pack_bits(jnp.asarray(rng.integers(0, 2, d).astype(np.uint32)), 32)
    dot = int(ref.packed_dot(pa, pb, d))
    assert abs(dot) <= d
    assert (dot + d) % 2 == 0


def test_eq2_example_msb_first():
    # element 0 occupies the highest bit of the word
    w = np.asarray(ref.pack_bits(jnp.array([[1, 0, 1, 1]], dtype=jnp.uint32), 4))
    assert w.tolist() == [[0b1011]]
    w = np.asarray(ref.pack_bits(jnp.array([[1, 0, 0]], dtype=jnp.uint32), 32))
    assert w.tolist() == [[0b100 << 29]]


def test_tail_bits_are_zero():
    w = np.asarray(ref.pack_bits(jnp.ones((1, 3), dtype=jnp.uint32), 32))
    assert w[0, 0] == 0b111 << 29


def test_sign_of_zero_is_minus_one():
    out = np.asarray(ref.sign_pm1(jnp.array([-1.0, 0.0, 1e-9, 2.0])))
    np.testing.assert_array_equal(out, [-1.0, -1.0, 1.0, 1.0])


@settings(max_examples=30, deadline=None)
@given(st.integers(1, 64), st.integers(1, 6), st.integers(0, 2**31))
def test_packed_matmul_matches_rowwise_dot(d, n, seed):
    rng = np.random.default_rng(seed)
    a = rng.integers(0, 2, (5, d)).astype(np.uint32)
    w = rng.integers(0, 2, (n, d)).astype(np.uint32)
    pa = ref.pack_bits(jnp.asarray(a), 32)
    pw = ref.pack_bits(jnp.asarray(w), 32)
    got = np.asarray(ref.packed_matmul(pa, pw, d))
    for i in range(5):
        for j in range(n):
            want = int(ref.packed_dot(pa[i], pw[j], d))
            assert got[i, j] == want
