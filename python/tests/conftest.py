import os
import sys

# Run from anywhere: make `compile.*` importable.
sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))
