"""SynthVehicles dataset invariants + augmentation protocol."""

import numpy as np

from compile import data as D


def test_render_deterministic():
    a = D.render_vehicle(5)
    b = D.render_vehicle(5)
    np.testing.assert_array_equal(a.image, b.image)
    assert a.label == 5 % 4


def test_render_range_and_shape():
    s = D.render_vehicle(0)
    assert s.image.shape == (96, 96, 3)
    assert s.image.dtype == np.float32
    assert s.image.min() >= 0.0 and s.image.max() <= 1.0


def test_labels_balanced():
    _, labels = D.generate(16)
    assert [int(l) for l in labels] == [i % 4 for i in range(16)]


def test_split_is_disjoint_and_complete():
    tr, te = D.split_indices(200)
    assert len(set(tr) & set(te)) == 0
    assert len(tr) + len(te) == 200
    assert len(te) == 20  # 10%


def test_split_deterministic():
    a = D.split_indices(100)
    b = D.split_indices(100)
    np.testing.assert_array_equal(a[0], b[0])
    np.testing.assert_array_equal(a[1], b[1])


def test_augment_grows_by_flip_plus_blur():
    images, labels = D.generate(40)
    xa, ya = D.augment(images, labels)
    assert len(xa) == len(ya)
    assert len(xa) >= 2 * len(images)  # at least the flips
    assert len(xa) <= 3 * len(images)
    # the flipped block mirrors the originals
    np.testing.assert_array_equal(xa[len(images)], images[0][:, ::-1, :])


def test_gaussian_blur_preserves_mean_and_smooths():
    rng = np.random.default_rng(0)
    img = rng.random((96, 96, 3)).astype(np.float32)
    blurred = D.gaussian_blur_05(img)
    assert blurred.shape == img.shape
    assert abs(float(img.mean()) - float(blurred.mean())) < 1e-3
    # smoothing reduces total variation
    tv = lambda x: float(np.abs(np.diff(x, axis=0)).mean() + np.abs(np.diff(x, axis=1)).mean())
    assert tv(blurred) < tv(img)


def test_splitmix_matches_rust_reference_vector():
    # same vector asserted in rust/src/util/rng.rs — keeps the two
    # implementations in lock-step
    out = D._splitmix64_stream(0, 3)
    assert out[0] == 0xE220A8397B1DCDAF
    assert out[1] == 0x6E789E6AA1B965F4
    assert out[2] == 0x06C45D188009454F


def test_classes_have_distinct_statistics():
    mean_of = lambda lbl: np.mean(
        [D.render_vehicle(i).image.mean() for i in range(40) if i % 4 == lbl]
    )
    assert abs(mean_of(2) - mean_of(1)) > 0.01  # truck vs car
