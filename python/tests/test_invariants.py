"""The Rust tree's source invariants hold — and the checker can fail.

Thin pytest wrapper around scripts/check_invariants.py (so the lint
suite runs with the regular suite as well as in its dedicated CI jobs),
plus negative tests: each rule is pointed at a deliberately-broken tmp
tree and must report the violation — a guard that cannot fail proves
nothing.
"""

import importlib.util
import subprocess
import sys
from pathlib import Path

REPO = Path(__file__).resolve().parents[2]
CHECKER = REPO / "scripts" / "check_invariants.py"


def load_checker():
    spec = importlib.util.spec_from_file_location("check_invariants", CHECKER)
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


def write_rs(root: Path, rel: str, text: str) -> None:
    path = root / rel
    path.parent.mkdir(parents=True, exist_ok=True)
    path.write_text(text)


def test_repo_passes_all_invariants():
    proc = subprocess.run(
        [sys.executable, str(CHECKER)], capture_output=True, text=True, check=False
    )
    assert proc.returncode == 0, f"invariant violations:\n{proc.stdout}{proc.stderr}"
    assert "ok:" in proc.stdout


def test_missing_write_coverage_doc_is_reported(tmp_path):
    mod = load_checker()
    write_rs(
        tmp_path,
        "rust/src/bnn/kern.rs",
        "/// Some doc without the contract line.\n"
        "pub fn frob_into(out: &mut [u32]) { out[0] = 1; }\n"
        "#[cfg(test)]\n"
        "mod tests { fn t() { super::frob_into(&mut [0]); } }\n",
    )
    errors = mod.check_write_coverage(tmp_path)
    assert len(errors) == 1
    assert "frob_into" in errors[0] and "Write coverage" in errors[0]


def test_untested_into_kernel_is_reported(tmp_path):
    mod = load_checker()
    write_rs(
        tmp_path,
        "rust/src/bnn/kern.rs",
        "/// Write coverage: assigns every element of `out`.\n"
        "pub fn frob_into(out: &mut [u32]) { out[0] = 1; }\n",
    )
    errors = mod.check_write_coverage(tmp_path)
    assert len(errors) == 1
    assert "never referenced" in errors[0]


def test_compliant_into_kernel_passes(tmp_path):
    mod = load_checker()
    write_rs(
        tmp_path,
        "rust/src/bnn/kern.rs",
        "/// Write coverage: assigns every element of `out`.\n"
        "#[inline]\n"
        "pub fn frob_into(out: &mut [u32]) { out[0] = 1; }\n"
        "#[cfg(test)]\n"
        "mod tests { fn t() { super::frob_into(&mut [0]); } }\n",
    )
    assert mod.check_write_coverage(tmp_path) == []


def test_bare_unwrap_in_serving_plane_is_reported(tmp_path):
    mod = load_checker()
    write_rs(
        tmp_path,
        "rust/src/coordinator/w.rs",
        "pub fn f(v: Vec<u32>) -> u32 { *v.first().unwrap() }\n",
    )
    errors = mod.check_panic_policy(tmp_path)
    assert len(errors) == 1
    assert "bare .unwrap()" in errors[0]


def test_lock_poisoning_unwrap_is_allowed(tmp_path):
    mod = load_checker()
    write_rs(
        tmp_path,
        "rust/src/registry/w.rs",
        "pub fn f(m: &std::sync::Mutex<u32>) -> u32 { *m.lock().unwrap() }\n",
    )
    assert mod.check_panic_policy(tmp_path) == []


def test_unwrap_in_cfg_test_region_is_exempt(tmp_path):
    mod = load_checker()
    write_rs(
        tmp_path,
        "rust/src/server/w.rs",
        "pub fn f() {}\n"
        "#[cfg(test)]\n"
        "mod tests { fn t() { Some(1).unwrap(); } }\n",
    )
    assert mod.check_panic_policy(tmp_path) == []


def test_empty_expect_message_is_reported(tmp_path):
    mod = load_checker()
    write_rs(
        tmp_path,
        "rust/src/server/w.rs",
        'pub fn f() { Some(1).expect(""); }\n',
    )
    errors = mod.check_panic_policy(tmp_path)
    assert len(errors) == 1
    assert "non-empty" in errors[0]


def test_hand_rolled_error_enum_is_reported(tmp_path):
    mod = load_checker()
    write_rs(
        tmp_path,
        "rust/src/util/e.rs",
        "pub enum FrobError { Bad }\n"
        "impl std::fmt::Display for FrobError { /* hand-rolled */ }\n",
    )
    errors = mod.check_error_enums(tmp_path)
    assert len(errors) == 1
    assert "FrobError" in errors[0]


def test_macro_backed_error_enum_passes(tmp_path):
    mod = load_checker()
    write_rs(
        tmp_path,
        "rust/src/util/e.rs",
        "pub enum FrobError { Bad }\n"
        'crate::error_enum_impls!(FrobError { FrobError::Bad => ("bad") });\n',
    )
    assert mod.check_error_enums(tmp_path) == []


GRAPH_ENUMS = (
    "pub enum LayerOp {\n"
    "    Frob { k: usize },\n"
    "    Quux,\n"
    "}\n"
)


def test_undocumented_ir_variant_is_reported(tmp_path):
    mod = load_checker()
    write_rs(tmp_path, "rust/src/bnn/graph/mod.rs", GRAPH_ENUMS)
    (tmp_path / "docs").mkdir()
    (tmp_path / "docs" / "ARCHITECTURE.md").write_text(
        "| op | effect |\n|---|---|\n| `Frob` | frobs |\n"
    )
    errors = mod.check_variant_coverage(tmp_path)
    assert len(errors) == 1
    assert "`Quux`" in errors[0] and "ARCHITECTURE.md" in errors[0]


def test_documented_ir_variants_pass(tmp_path):
    mod = load_checker()
    write_rs(tmp_path, "rust/src/bnn/graph/mod.rs", GRAPH_ENUMS)
    (tmp_path / "docs").mkdir()
    (tmp_path / "docs" / "ARCHITECTURE.md").write_text(
        "| op | effect |\n|---|---|\n| `Frob` | frobs |\n| `Quux` | quuxes |\n"
    )
    assert mod.check_variant_coverage(tmp_path) == []


def test_backtick_matching_is_exact_not_substring(tmp_path):
    # a row documenting `FrobPacked` must not satisfy `Frob`
    mod = load_checker()
    write_rs(tmp_path, "rust/src/bnn/graph/mod.rs", "pub enum LayerOp {\n    Frob,\n}\n")
    (tmp_path / "docs").mkdir()
    (tmp_path / "docs" / "ARCHITECTURE.md").write_text(
        "| op | effect |\n|---|---|\n| `FrobPacked` | frobs, packed |\n"
    )
    errors = mod.check_variant_coverage(tmp_path)
    assert len(errors) == 1 and "`Frob`" in errors[0]


def test_untested_corruption_variant_is_reported(tmp_path):
    mod = load_checker()
    write_rs(
        tmp_path,
        "rust/src/bnn/graph/plan.rs",
        "pub enum Corruption {\n    SlotMerge,\n    PadSmash,\n}\n"
        "#[cfg(test)]\n"
        "mod tests { fn t() { let _ = Corruption::SlotMerge; } }\n",
    )
    errors = mod.check_variant_coverage(tmp_path)
    assert len(errors) == 1
    assert "Corruption::PadSmash" in errors[0] and "never named" in errors[0]


def test_integration_test_reference_satisfies_rule_d(tmp_path):
    # files under rust/tests/ are whole-file test regions
    mod = load_checker()
    write_rs(
        tmp_path,
        "rust/src/bnn/graph/plan.rs",
        "pub enum Corruption {\n    SlotMerge,\n}\n",
    )
    write_rs(
        tmp_path,
        "rust/tests/integration_x.rs",
        "fn t() { let _ = Corruption::SlotMerge; }\n",
    )
    assert mod.check_variant_coverage(tmp_path) == []


def _write_arch_table(tmp_path: Path, rows: str) -> None:
    (tmp_path / "docs").mkdir(exist_ok=True)
    (tmp_path / "docs" / "ARCHITECTURE.md").write_text(
        "| metric | meaning |\n|---|---|\n" + rows
    )


def test_undocumented_metric_family_is_reported(tmp_path):
    mod = load_checker()
    write_rs(
        tmp_path,
        "rust/src/server/tcp.rs",
        'pub const M: &str = "bcnn_frobs_total";\n',
    )
    _write_arch_table(tmp_path, "| `bcnn_other_total` | other |\n")
    errors = mod.check_metric_docs(tmp_path)
    assert len(errors) == 1
    assert "`bcnn_frobs_total`" in errors[0] and "metric inventory" in errors[0]


def test_documented_metric_family_passes(tmp_path):
    mod = load_checker()
    write_rs(
        tmp_path,
        "rust/src/server/tcp.rs",
        'pub const M: &str = "bcnn_frobs_total";\n',
    )
    _write_arch_table(tmp_path, "| `bcnn_frobs_total` | frob count |\n")
    assert mod.check_metric_docs(tmp_path) == []


def test_metric_doc_match_is_exact_token_not_substring(tmp_path):
    # a row documenting `bcnn_frobs_total_v2` must not satisfy
    # `bcnn_frobs_total` (rule E matches like rule D: exact backticks)
    mod = load_checker()
    write_rs(
        tmp_path,
        "rust/src/server/tcp.rs",
        'pub const M: &str = "bcnn_frobs_total";\n',
    )
    _write_arch_table(tmp_path, "| `bcnn_frobs_total_v2` | not the same family |\n")
    errors = mod.check_metric_docs(tmp_path)
    assert len(errors) == 1 and "`bcnn_frobs_total`" in errors[0]


def test_metric_literal_in_test_region_is_exempt(tmp_path):
    # only PROD emission sites bind the inventory; tests may name
    # whatever families they like (e.g. golden-test scaffolding)
    mod = load_checker()
    write_rs(
        tmp_path,
        "rust/src/server/tcp.rs",
        "pub fn f() {}\n"
        "#[cfg(test)]\n"
        'mod tests { const M: &str = "bcnn_test_only_total"; }\n',
    )
    _write_arch_table(tmp_path, "")
    assert mod.check_metric_docs(tmp_path) == []


def test_non_family_literals_never_match(tmp_path):
    # lane keys ("bcnn_rgb@1") and embedded prefixes are not families:
    # both quotes must be adjacent to the name
    mod = load_checker()
    write_rs(
        tmp_path,
        "rust/src/server/tcp.rs",
        'pub const A: &str = "bcnn_rgb@1";\n'
        'pub const B: &str = "engine/bcnn_rgb";\n',
    )
    _write_arch_table(tmp_path, "")
    assert mod.check_metric_docs(tmp_path) == []


SIMD_FILE = "rust/src/bnn/microkernel/simd.rs"


def test_unsafe_optout_outside_audited_module_is_reported(tmp_path):
    mod = load_checker()
    write_rs(
        tmp_path,
        "rust/src/bnn/fastpath.rs",
        "#![allow(unsafe_code)]\npub fn f() {}\n",
    )
    errors = mod.check_unsafe_audit(tmp_path)
    assert len(errors) == 1
    assert "allow(unsafe_code)" in errors[0] and "fastpath.rs" in errors[0]


def test_unsafe_optout_in_audited_module_passes(tmp_path):
    mod = load_checker()
    write_rs(tmp_path, SIMD_FILE, "#![allow(unsafe_code)]\npub fn f() {}\n")
    assert mod.check_unsafe_audit(tmp_path) == []


def test_commented_unsafe_optout_is_exempt(tmp_path):
    mod = load_checker()
    write_rs(
        tmp_path,
        "rust/src/bnn/fastpath.rs",
        "// #![allow(unsafe_code)] would re-open the deny\npub fn f() {}\n",
    )
    assert mod.check_unsafe_audit(tmp_path) == []


def test_untested_target_feature_fn_is_reported(tmp_path):
    mod = load_checker()
    write_rs(
        tmp_path,
        SIMD_FILE,
        "#![allow(unsafe_code)]\n"
        '#[target_feature(enable = "avx2")]\n'
        "pub(super) unsafe fn pop_avx2_impl(a: &[u64]) -> u32 { 0 }\n",
    )
    errors = mod.check_unsafe_audit(tmp_path)
    assert len(errors) == 1
    assert "pop_avx2_impl" in errors[0] and "never named" in errors[0]


def test_bit_identity_tested_target_feature_fn_passes(tmp_path):
    mod = load_checker()
    write_rs(
        tmp_path,
        SIMD_FILE,
        "#![allow(unsafe_code)]\n"
        '#[target_feature(enable = "avx2")]\n'
        "pub(super) unsafe fn pop_avx2_impl(a: &[u64]) -> u32 { 0 }\n"
        "#[cfg(test)]\n"
        "mod tests { fn t() { let _ = unsafe { super::pop_avx2_impl(&[]) }; } }\n",
    )
    assert mod.check_unsafe_audit(tmp_path) == []


def test_doc_comment_mention_does_not_satisfy_rule_f(tmp_path):
    # the bit-identity reference must be code, not prose: a test-region
    # comment naming the fn is stripped before the search
    mod = load_checker()
    write_rs(
        tmp_path,
        SIMD_FILE,
        "#![allow(unsafe_code)]\n"
        '#[target_feature(enable = "avx2")]\n'
        "pub(super) unsafe fn pop_avx2_impl(a: &[u64]) -> u32 { 0 }\n"
        "#[cfg(test)]\n"
        "mod tests {\n"
        "    // bit-identity for pop_avx2_impl lives elsewhere (it must not)\n"
        "    fn t() {}\n"
        "}\n",
    )
    errors = mod.check_unsafe_audit(tmp_path)
    assert len(errors) == 1 and "pop_avx2_impl" in errors[0]


def test_main_reports_nonzero_on_broken_tree(tmp_path, monkeypatch):
    mod = load_checker()
    write_rs(
        tmp_path,
        "rust/src/bnn/kern.rs",
        "pub fn frob_into(out: &mut [u32]) { out[0] = 1; }\n",
    )
    write_rs(tmp_path, "rust/src/server/w.rs", "pub fn f() { Some(1).unwrap(); }\n")
    write_rs(tmp_path, "rust/src/coordinator/lib.rs", "pub fn g() {}\n")
    write_rs(tmp_path, "rust/src/registry/lib.rs", "pub fn h() {}\n")
    monkeypatch.setattr(mod, "REPO", tmp_path)
    assert mod.main() == 1
