"""Input binarization schemes (paper Section 2.3)."""

import jax.numpy as jnp
import numpy as np
import pytest

from compile import binarize_input as BI
from compile import data as D


@pytest.fixture(scope="module")
def image():
    return jnp.asarray(D.render_vehicle(1).image)


def test_threshold_rgb_outputs_pm1(image):
    out = np.asarray(BI.threshold_rgb(image, jnp.array([-0.5, -0.5, -0.5])))
    assert set(np.unique(out)) <= {-1.0, 1.0}
    assert out.shape == (96, 96, 3)


def test_threshold_rgb_threshold_shifts_balance(image):
    lo = np.asarray(BI.threshold_rgb(image, jnp.array([-0.1, -0.1, -0.1])))
    hi = np.asarray(BI.threshold_rgb(image, jnp.array([-0.9, -0.9, -0.9])))
    # a higher threshold (more negative T) fires fewer +1s
    assert hi.sum() < lo.sum()


def test_threshold_gray_single_channel(image):
    out = np.asarray(BI.threshold_gray(image, jnp.array([-0.5])))
    assert out.shape == (96, 96, 1)
    assert set(np.unique(out)) <= {-1.0, 1.0}


def test_lbp_three_channels_pm1(image):
    out = np.asarray(BI.lbp(image))
    assert out.shape == (96, 96, 3)
    assert set(np.unique(out)) <= {-1.0, 1.0}


def test_lbp_flat_image_all_minus_one():
    flat = jnp.full((1, 8, 8, 3), 0.5)
    out = np.asarray(BI.lbp(flat))
    assert (out == -1.0).all()


def test_lbp_detects_gradient():
    # horizontal ramp: right neighbour (select index 3) always brighter
    ramp = jnp.tile(jnp.linspace(0, 1, 8)[None, :, None], (8, 1, 3))
    out = np.asarray(BI.lbp(ramp))
    # channel 1 = neighbour (0,+1): +1 everywhere except the last column
    assert (out[:, :-1, 1] == 1.0).all()
    assert (out[:, -1, 1] == -1.0).all()


def test_apply_scheme_dispatch(image):
    params = {"input_t": jnp.array([-0.5, -0.5, -0.5])}
    for scheme in BI.SCHEMES:
        p = dict(params)
        if scheme == "gray":
            p["input_t"] = jnp.array([-0.5])
        out, c = BI.apply_scheme(scheme, image, p)
        assert c == BI.input_channels(scheme)
        if scheme == "none":
            np.testing.assert_array_equal(np.asarray(out), np.asarray(image))
        else:
            assert set(np.unique(np.asarray(out))) <= {-1.0, 1.0}


def test_unknown_scheme_raises(image):
    with pytest.raises(ValueError):
        BI.apply_scheme("bogus", image, {})
