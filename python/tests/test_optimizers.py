"""Hand-rolled Adam / RMSprop sanity: quadratic convergence + known-step
checks."""

import jax
import jax.numpy as jnp
import numpy as np

from compile import optimizers


def _minimize(opt, steps=200):
    params = {"x": jnp.array([5.0, -3.0])}
    state = opt.init(params)
    target = jnp.array([1.0, 2.0])

    @jax.jit
    def step(p, s):
        grads = jax.grad(lambda q: jnp.sum((q["x"] - target) ** 2))(p)
        return opt.update(grads, s, p)

    for _ in range(steps):
        params, state = step(params, state)
    return np.asarray(params["x"])


def test_adam_converges_on_quadratic():
    x = _minimize(optimizers.adam(0.1))
    np.testing.assert_allclose(x, [1.0, 2.0], atol=1e-2)


def test_rmsprop_converges_on_quadratic():
    x = _minimize(optimizers.rmsprop(0.05))
    np.testing.assert_allclose(x, [1.0, 2.0], atol=5e-2)


def test_adam_first_step_magnitude():
    # with bias correction, the first Adam step is ~lr * sign(grad)
    opt = optimizers.adam(0.1)
    params = {"x": jnp.array([1.0])}
    state = opt.init(params)
    grads = {"x": jnp.array([123.0])}
    new_params, _ = opt.update(grads, state, params)
    np.testing.assert_allclose(np.asarray(new_params["x"]), [1.0 - 0.1], atol=1e-5)


def test_rmsprop_scales_by_rms():
    opt = optimizers.rmsprop(0.1, decay=0.0)  # s = g^2 immediately
    params = {"x": jnp.array([0.0])}
    state = opt.init(params)
    grads = {"x": jnp.array([4.0])}
    new_params, _ = opt.update(grads, state, params)
    # step = lr * g / sqrt(g^2) = lr
    np.testing.assert_allclose(np.asarray(new_params["x"]), [-0.1], atol=1e-6)


def test_state_shapes_match_params():
    opt = optimizers.adam()
    params = {"a": jnp.zeros((3, 4)), "b": jnp.zeros(7)}
    state = opt.init(params)
    assert state["m"]["a"].shape == (3, 4)
    assert state["v"]["b"].shape == (7,)
