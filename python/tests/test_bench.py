"""The advisory bench harness works without a Rust toolchain.

scripts/bench shells out to `cargo bench` in real use; these tests
drive the whole discover -> run -> emit -> diff pipeline through the
`BCNN_BENCH_RUNNER` stub seam, and pin the no-cargo skip path, so the
harness itself is covered on machines (and CI lanes) with no cargo.
"""

import json
import os
import re
import subprocess
import sys
from pathlib import Path

REPO = Path(__file__).resolve().parents[2]
BENCH = REPO / "scripts" / "bench"


def declared_targets() -> list[str]:
    text = (REPO / "Cargo.toml").read_text()
    return re.findall(r'\[\[bench\]\]\s*\nname\s*=\s*"([^"]+)"', text)


def run_bench(tmp_path, stub_body: str | None, *args: str, expect_rc: int = 0):
    env = dict(os.environ)
    env.pop("BCNN_BENCH_RUNNER", None)
    if stub_body is None:
        # force cargo off PATH so the skip path is deterministic even
        # on hosts that have a toolchain
        empty = tmp_path / "emptybin"
        empty.mkdir(exist_ok=True)
        env["PATH"] = str(empty)
    else:
        stub = tmp_path / "stub_runner.py"
        stub.write_text(stub_body)
        env["BCNN_BENCH_RUNNER"] = f"{sys.executable} {stub}"
    proc = subprocess.run(
        [sys.executable, str(BENCH), "--out", str(tmp_path / "out"), *args],
        capture_output=True,
        text=True,
        env=env,
        check=False,
    )
    assert proc.returncode == expect_rc, f"rc={proc.returncode}\n{proc.stdout}{proc.stderr}"
    return proc.stdout


OK_STUB = (
    "import sys\n"
    "print(f'bench {sys.argv[1]}')\n"
    "print('mean_us: 100.0')\n"
    "print('throughput = 2000 img/s')\n"
)

SLOWER_STUB = OK_STUB.replace("100.0", "150.0")

FAIL_STUB = "import sys\nprint('boom')\nsys.exit(1)\n"


def test_cargo_toml_declares_the_full_bench_suite():
    # the harness discovers targets from Cargo.toml; the suite the
    # ISSUE names is thirteen strong and growing — never shrinking
    assert len(declared_targets()) >= 13
    # the microkernel ablation registered itself for auto-discovery
    assert "ablation_microkernel" in declared_targets()


def test_skips_cleanly_when_cargo_is_absent(tmp_path):
    out = run_bench(tmp_path, None)
    assert "skip: cargo not found" in out
    assert not (tmp_path / "out").exists(), "a skip writes nothing"


def test_stub_runner_emits_one_json_per_declared_target(tmp_path):
    out = run_bench(tmp_path, OK_STUB)
    targets = declared_targets()
    for name in targets:
        result_path = tmp_path / "out" / f"BENCH_{name}.json"
        assert result_path.is_file(), out
        result = json.loads(result_path.read_text())
        assert result["name"] == name and result["ok"]
        assert result["samples"]["mean_us"] == 100.0
        assert result["samples"]["throughput [img/s]"] == 2000.0
    assert out.count("bench ") >= len(targets)
    assert "no advisory drift" in out, "first run has nothing to diff"


def test_drift_beyond_threshold_is_advisory_not_fatal(tmp_path):
    run_bench(tmp_path, OK_STUB, "--only", "table1_e2e")
    out = run_bench(tmp_path, SLOWER_STUB, "--only", "table1_e2e", expect_rc=0)
    assert "advisory" in out and "regressed" in out and "+50.0%" in out
    # the new numbers replace the old baseline
    result = json.loads((tmp_path / "out" / "BENCH_table1_e2e.json").read_text())
    assert result["samples"]["mean_us"] == 150.0


def test_within_threshold_moves_stay_quiet(tmp_path):
    run_bench(tmp_path, OK_STUB, "--only", "table1_e2e")
    nearby = OK_STUB.replace("100.0", "104.0")  # +4% < the 10% gate
    out = run_bench(tmp_path, nearby, "--only", "table1_e2e")
    assert "no advisory drift" in out


def test_failing_bench_target_fails_the_harness(tmp_path):
    out = run_bench(tmp_path, FAIL_STUB, "--only", "table1_e2e", expect_rc=1)
    assert "FAILED" in out and "boom" in out
    result = json.loads((tmp_path / "out" / "BENCH_table1_e2e.json").read_text())
    assert not result["ok"] and result["samples"] == {}


def test_unknown_only_target_is_an_error(tmp_path):
    out = run_bench(tmp_path, OK_STUB, "--only", "no_such_bench", expect_rc=1)
    assert "unknown bench target" in out
