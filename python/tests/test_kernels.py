"""Pallas kernels vs the pure-jnp oracles in ref.py — the core L1
correctness signal.  Seeded sweeps cover shapes/bitwidths (the offline
image carries no hypothesis, so cases are enumerated deterministically);
every comparison is exact (bit-level), not allclose, because the
binarized pipeline is integer arithmetic end to end."""

import itertools

import jax.numpy as jnp
import numpy as np
import pytest

from compile.kernels import bgemm, fc_packed, im2col_pack, maxpool, ref, sign_pack


# ---------------------------------------------------------------------------
# sign_pack
# ---------------------------------------------------------------------------


@pytest.mark.parametrize(
    "n,d,b,seed",
    [
        (n, d, b, seed)
        for (n, d), (b, seed) in itertools.product(
            [(1, 3), (7, 32), (16, 75), (40, 100)],
            [(8, 0), (25, 1), (32, 2)],
        )
    ],
)
def test_sign_pack_matches_ref(n, d, b, seed):
    rng = np.random.default_rng(seed * 7919 + n * 31 + d)
    x = rng.standard_normal((n, d)).astype(np.float32)
    got = np.asarray(sign_pack.sign_pack(jnp.asarray(x), b=b, block_rows=16))
    want = np.asarray(ref.pack_bits(ref.pm1_to_bits(ref.sign_pm1(jnp.asarray(x))), b))
    np.testing.assert_array_equal(got, want)


def test_sign_pack_zero_input_packs_to_zero():
    # sign(0) = -1 -> bit 0 everywhere
    out = np.asarray(sign_pack.sign_pack(jnp.zeros((4, 64)), b=32))
    assert (out == 0).all()


# ---------------------------------------------------------------------------
# im2col_pack (Algorithm 1)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize(
    "hwc,k,b,seed",
    [
        (hwc, k, b, seed)
        for hwc, (k, b, seed) in itertools.product(
            [(8, 8, 3), (12, 8, 1), (8, 12, 32), (16, 16, 4)],
            [(3, 25, 0), (3, 32, 1), (5, 25, 2), (5, 32, 3)],
        )
    ],
)
def test_im2col_pack_matches_ref(hwc, k, b, seed):
    h, w, c = hwc
    rng = np.random.default_rng(seed * 104729 + h * 64 + w)
    x = np.where(rng.standard_normal((h, w, c)) > 0, 1.0, -1.0).astype(np.float32)
    got = np.asarray(im2col_pack.im2col_pack(jnp.asarray(x), k=k, b=b, s=2))
    want = np.asarray(ref.im2col_pack(jnp.asarray(x), k, b))
    np.testing.assert_array_equal(got, want)


def test_im2col_pack_paper_shape():
    # the paper's first layer: (96,96,3), K=5 -> 9216 x ceil(75/32)
    x = jnp.ones((96, 96, 3), jnp.float32)
    out = im2col_pack.im2col_pack(x, k=5, b=32)
    assert out.shape == (9216, 3)


def test_im2col_border_packs_padding_as_minus_one():
    # all-(+1) image: interior patches = all ones; the top-left corner
    # patch must contain 0-bits exactly at the halo positions
    x = jnp.ones((8, 8, 1), jnp.float32)
    out = np.asarray(im2col_pack.im2col_pack(x, k=3, b=32))
    corner_bits = np.asarray(ref.unpack_bits(jnp.asarray(out[0:1]), 9, 32))[0]
    np.testing.assert_array_equal(corner_bits, [0, 0, 0, 0, 1, 1, 0, 1, 1])


# ---------------------------------------------------------------------------
# bgemm (Eq. 4)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize(
    "m,n,d,seed",
    [
        (m, n, d, seed)
        for (m, n), (d, seed) in itertools.product(
            [(1, 1), (13, 8), (100, 32)],
            [(25, 0), (75, 1), (128, 2), (800, 3)],
        )
    ],
)
def test_bgemm_matches_ref(m, n, d, seed):
    rng = np.random.default_rng(seed * 6151 + m * 17 + n)
    ab = rng.integers(0, 2, (m, d)).astype(np.uint32)
    wb = rng.integers(0, 2, (n, d)).astype(np.uint32)
    ap = ref.pack_bits(jnp.asarray(ab), 32)
    wp = ref.pack_bits(jnp.asarray(wb), 32)
    got = np.asarray(bgemm.bgemm(ap, wp, d, bm=64, bn=16))
    want = np.asarray(ref.packed_matmul(ap, wp, d))
    np.testing.assert_array_equal(got, want)


def test_bgemm_conv_equivalence():
    # packed conv == ±1 integer conv (DESIGN invariant)
    rng = np.random.default_rng(7)
    x = np.where(rng.standard_normal((16, 16, 3)) > 0, 1.0, -1.0).astype(np.float32)
    w = np.where(rng.standard_normal((8, 5, 5, 3)) > 0, 1.0, -1.0).astype(np.float32)
    packed = np.asarray(ref.conv2d_packed(jnp.asarray(x), jnp.asarray(w)))
    direct = np.asarray(ref.conv2d_pm1(jnp.asarray(x), jnp.asarray(w)))
    np.testing.assert_array_equal(packed, direct.astype(np.int32))


def test_fgemm_matches_matmul():
    rng = np.random.default_rng(3)
    a = rng.standard_normal((50, 75)).astype(np.float32)
    w = rng.standard_normal((32, 75)).astype(np.float32)
    got = np.asarray(bgemm.fgemm(jnp.asarray(a), jnp.asarray(w), bm=16, bn=16))
    np.testing.assert_allclose(got, a @ w.T, rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# pooling
# ---------------------------------------------------------------------------


@pytest.mark.parametrize(
    "hwc,seed",
    [
        (hwc, seed)
        for hwc, seed in itertools.product(
            [(8, 8, 5), (16, 4, 2), (4, 16, 32)], range(5)
        )
    ],
)
def test_maxpool_matches_ref(hwc, seed):
    h, w, c = hwc
    rng = np.random.default_rng(seed * 433 + h)
    x = rng.standard_normal((h, w, c)).astype(np.float32)
    got = np.asarray(maxpool.maxpool2x2(jnp.asarray(x), block_rows=2))
    want = np.asarray(ref.maxpool2x2(jnp.asarray(x)))
    np.testing.assert_array_equal(got, want)


@pytest.mark.parametrize(
    "hwn,seed",
    [
        (hwn, seed)
        for hwn, seed in itertools.product([(8, 8, 1), (16, 8, 3)], range(5))
    ],
)
def test_orpool_matches_ref(hwn, seed):
    h, w, nw = hwn
    rng = np.random.default_rng(seed * 911 + w)
    words = rng.integers(0, 2**32, (h, w, nw), dtype=np.uint64).astype(np.uint32)
    got = np.asarray(maxpool.orpool2x2(jnp.asarray(words), block_rows=2))
    want = np.asarray(ref.orpool2x2_packed(jnp.asarray(words)))
    np.testing.assert_array_equal(got, want)


def test_orpool_equals_sign_of_maxpool():
    # sign monotonicity: or(sign(x)) == sign(max(x)) channel-wise
    rng = np.random.default_rng(11)
    x = rng.standard_normal((8, 8, 32)).astype(np.float32)
    bits = ref.pm1_to_bits(ref.sign_pm1(jnp.asarray(x)))
    words = ref.pack_bits(bits, 32)  # (8,8,1)
    a = np.asarray(ref.orpool2x2_packed(words))
    pooled = ref.maxpool2x2(jnp.asarray(x))
    b = np.asarray(ref.pack_bits(ref.pm1_to_bits(ref.sign_pm1(pooled)), 32))
    np.testing.assert_array_equal(a, b)


# ---------------------------------------------------------------------------
# fc_packed (Section 3.2)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize(
    "l,kw,seed",
    [
        (l, kw, seed)
        for (l, seed), kw in itertools.product(
            [(1, 0), (37, 1), (120, 2)], [64, 576, 1024]
        )
    ],
)
def test_fc_packed_matches_ref(l, kw, seed):
    rng = np.random.default_rng(seed * 271 + l)
    d = kw * 32
    x = rng.integers(0, 2**32, kw, dtype=np.uint64).astype(np.uint32)
    w = rng.integers(0, 2**32, (l, kw), dtype=np.uint64).astype(np.uint32)
    got = np.asarray(fc_packed.fc_packed(jnp.asarray(x), jnp.asarray(w), d))
    want = np.asarray(ref.fc_packed(jnp.asarray(x), jnp.asarray(w), d))
    np.testing.assert_array_equal(got, want)


def test_fc_packed_segment_padding():
    # KW not a multiple of 64 segments exercises the zero-pad path
    rng = np.random.default_rng(5)
    kw, l, d = 18, 7, 18 * 32
    x = rng.integers(0, 2**32, kw, dtype=np.uint64).astype(np.uint32)
    w = rng.integers(0, 2**32, (l, kw), dtype=np.uint64).astype(np.uint32)
    got = np.asarray(fc_packed.fc_packed(jnp.asarray(x), jnp.asarray(w), d))
    want = np.asarray(ref.fc_packed(jnp.asarray(x), jnp.asarray(w), d))
    np.testing.assert_array_equal(got, want)
