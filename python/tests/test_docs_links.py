"""The docs tree must not rot: every intra-repo markdown link resolves.

Thin pytest wrapper around scripts/check_docs_links.py so the link
check runs with the regular suite as well as in its dedicated CI job.
"""

import subprocess
import sys
from pathlib import Path

REPO = Path(__file__).resolve().parents[2]
CHECKER = REPO / "scripts" / "check_docs_links.py"


def test_docs_tree_exists():
    # the documented entry points of the docs tree
    assert (REPO / "docs" / "ARCHITECTURE.md").is_file()
    assert (REPO / "docs" / "PROTOCOL.md").is_file()


def test_intra_repo_links_resolve():
    proc = subprocess.run(
        [sys.executable, str(CHECKER)], capture_output=True, text=True, check=False
    )
    assert proc.returncode == 0, f"broken docs links:\n{proc.stdout}{proc.stderr}"


def test_checker_catches_broken_links(tmp_path, monkeypatch):
    # the guard itself must fail when a link is broken — otherwise a
    # green check proves nothing (regression test for the checker)
    import importlib.util

    spec = importlib.util.spec_from_file_location("check_docs_links", CHECKER)
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)

    bad = tmp_path / "docs"
    bad.mkdir()
    (bad / "bad.md").write_text("[missing](does-not-exist.md)\n")
    monkeypatch.setattr(mod, "REPO", tmp_path)
    assert mod.main() == 1
