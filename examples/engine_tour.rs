//! Engine tour: walk one image through every binarized layer, printing
//! the intermediate representations and sizes — a didactic companion to
//! Section 3 of the paper (and a handy debugging harness).
//!
//!     cargo run --release --example engine_tour

use bcnn::bnn::{bgemm, fc, im2col, maxpool, packing};
use bcnn::dataset::synth;
use bcnn::input::binarize;
use bcnn::util::rng::Xoshiro256;

fn main() {
    let s = synth::render_vehicle(7, synth::DEFAULT_SEED);
    println!("input image: 96x96x3 f32 ({} bytes)\n", s.image.len() * 4);

    // --- Section 2.3: input binarization ---------------------------------
    let xb = binarize::threshold_rgb(&s.image, &[-0.5, -0.5, -0.5]);
    let plus = xb.iter().filter(|&&v| v > 0.0).count();
    println!(
        "1. threshold_rgb -> ±1 image, {plus}/{} bits set (+1)",
        xb.len()
    );

    // --- Algorithm 1: fused im2col + pack ---------------------------------
    let cols = im2col::im2col_pack(&xb, 96, 96, 3, 5, 32);
    println!(
        "2. im2col_pack (K=5, B=32): 9216 patches x {} words = {} bytes \
         (float im2col would be {} bytes — {}x compression)",
        cols.len() / 9216,
        cols.len() * 4,
        9216 * 75 * 4,
        9216 * 75 * 4 / (cols.len() * 4)
    );

    // --- Eq. 4: xnor-popcount GEMM ----------------------------------------
    let mut rng = Xoshiro256::new(1);
    let w1: Vec<f32> = (0..32 * 75).map(|_| rng.next_pm1()).collect();
    let mut w1p = Vec::new();
    for o in 0..32 {
        w1p.extend(packing::pack_pm1(&w1[o * 75..(o + 1) * 75], 32));
    }
    let counts = bgemm::bgemm(&cols, &w1p, 9216, 32, 3, 75);
    let (lo, hi) = (counts.iter().min().unwrap(), counts.iter().max().unwrap());
    println!(
        "3. bgemm: (9216x3words) x (32x3words) -> 9216x32 i32 counts in [-75, 75], \
         observed [{lo}, {hi}]"
    );

    // --- threshold + channel pack -----------------------------------------
    let theta = vec![0.0f32; 32];
    let flip = vec![0u32; 32];
    let mut words = vec![0u32; 9216];
    for px in 0..9216 {
        let mut w = 0u32;
        for ch in 0..32 {
            w |= packing::threshold_bit(counts[px * 32 + ch] as f32, theta[ch], flip[ch])
                << (31 - ch);
        }
        words[px] = w;
    }
    println!("4. threshold+pack: 9216x32 i32 -> 9216 u32 words (32 channels/word)");

    // --- OR-pool ------------------------------------------------------------
    let pooled = maxpool::orpool2x2(&words, 96, 96, 1);
    println!(
        "5. orpool2x2: 96x96 words -> 48x48 words ({} bytes; float pool moves {} bytes)",
        pooled.len() * 4,
        96 * 96 * 32 * 4
    );

    // --- conv2 in the packed domain ------------------------------------------
    let cols2 = im2col::im2col_words(&pooled, 48, 48, 1, 5);
    let w2: Vec<u32> = (0..32 * 25).map(|_| rng.next_u32()).collect();
    let counts2 = bgemm::bgemm(&cols2, &w2, 2304, 32, 25, 800);
    println!(
        "6. im2col_words + bgemm: patch = 25 pre-packed words, D = 800 bits, \
         counts2 range [{}, {}]",
        counts2.iter().min().unwrap(),
        counts2.iter().max().unwrap()
    );

    // --- packed FC --------------------------------------------------------------
    let mut words2 = vec![0u32; 2304];
    for px in 0..2304 {
        let mut w = 0u32;
        for ch in 0..32 {
            w |= packing::threshold_bit(counts2[px * 32 + ch] as f32, 0.0, 0) << (31 - ch);
        }
        words2[px] = w;
    }
    let pooled2 = maxpool::orpool2x2(&words2, 48, 48, 1); // 576 words
    let wfc: Vec<u32> = (0..100 * 576).map(|_| rng.next_u32()).collect();
    let fc_out = fc::fc_packed(&pooled2, &wfc, 100, 576, 18432);
    println!(
        "7. fc_packed: 576 words (= 18432 bits) x 100 neurons -> counts in [{}, {}]",
        fc_out.iter().min().unwrap(),
        fc_out.iter().max().unwrap()
    );
    println!(
        "\nweights footprint: conv1 {}B + conv2 {}B + fc1 {}B = {} bytes total \
         (float: {} bytes — 32x)",
        32 * 3 * 4,
        32 * 25 * 4,
        100 * 576 * 4,
        32 * 3 * 4 + 32 * 25 * 4 + 100 * 576 * 4,
        (32 * 75 + 32 * 800 + 100 * 18432) * 4
    );
}
