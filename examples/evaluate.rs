//! Table 3 reproduction: test-set accuracy for every input-binarization
//! scheme + the full-precision network, on the canonical test split
//! (`artifacts/testset.bcnt`).
//!
//! Run `make train` first so the artifacts carry trained weights; with
//! random-init weights the table prints (and says so) but accuracies
//! hover at chance.
//!
//!     cargo run --release --example evaluate

use bcnn::bnn::network::{argmax, BcnnNetwork, FloatNetwork};
use bcnn::dataset::testset::TestSet;
use bcnn::input::binarize::Scheme;
use bcnn::runtime::Artifacts;
use bcnn::util::threadpool::{default_threads, scoped_map};

/// Paper Table 3 reference values.
const PAPER: [(&str, &str, f64); 5] = [
    ("lbp", "LBP", 92.06),
    ("gray", "Thresholding Grayscale", 89.16),
    ("rgb", "Thresholding RGB", 92.52),
    ("none", "No input binarization", 94.20),
    ("float", "Full-precision network", 97.09),
];

use bcnn::util::error::AppResult;

fn main() -> AppResult<()> {
    let artifacts = Artifacts::load("artifacts")
        .map_err(|e| bcnn::app_err!("{e}\nhint: run `make artifacts` first"))?;
    let ts = TestSet::load(
        artifacts.testset_path().ok_or_else(|| bcnn::app_err!("no testset in manifest"))?,
    )?;
    let threads = default_threads();
    let n = ts.len();
    let all_trained = artifacts.trained.iter().all(|(_, t)| *t);
    println!(
        "Table 3 — accuracy vs input-binarization scheme ({n} test images, {})",
        if all_trained { "trained weights" } else { "RANDOM-INIT weights — run `make train`" }
    );
    println!("{:<26}{:>12}{:>12}", "method", "this repo", "paper");

    for (variant, label, paper_acc) in PAPER {
        let correct: usize = if variant == "float" {
            let net = FloatNetwork::load(artifacts.path_of("weights_float.bcnt"))?;
            scoped_map(n, threads, |i| {
                usize::from(argmax(&net.forward(ts.image(i)).0) as i32 == ts.labels[i])
            })
            .into_iter()
            .sum()
        } else {
            let scheme = Scheme::parse(variant).unwrap();
            let net = BcnnNetwork::load(
                artifacts.path_of(&format!("weights_bcnn_{variant}.bcnt")),
                scheme,
            )?;
            scoped_map(n, threads, |i| {
                usize::from(argmax(&net.forward(ts.image(i)).0) as i32 == ts.labels[i])
            })
            .into_iter()
            .sum()
        };
        println!(
            "{:<26}{:>11.2}%{:>11.2}%",
            label,
            100.0 * correct as f64 / n as f64,
            paper_acc
        );
    }
    println!(
        "\nexpected ordering (paper): float > none > rgb ≈ lbp > gray\n\
         (dataset is the SynthVehicles substitute — absolute numbers differ,\n\
          the ordering is the reproduced claim; see DESIGN.md §2)"
    );
    Ok(())
}
