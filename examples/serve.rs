//! End-to-end serving driver — the paper's measurement protocol (Section
//! 2.2) run through the full coordinator stack:
//!
//!   1000 synthetic images, one request at a time, through
//!   admission → dynamic batcher → backend → response,
//!   for both the full-precision and binarized models.
//!
//! Reports per-variant accuracy (vs the synthetic ground truth), mean /
//! p50 / p95 / p99 latency, throughput, and the binarized speedup —
//! the e2e row of Table 1 on this testbed.  Also exercises the TCP front
//! end with a burst of client connections.
//!
//!     cargo run --release --example serve -- [--requests 1000] [--pjrt]

use std::sync::atomic::AtomicBool;
use std::sync::Arc;
use std::time::Instant;

use bcnn::bnn::network::{BcnnNetwork, FloatNetwork, CLASSES};
use bcnn::coordinator::{BatchPolicy, EngineBackend, InferBackend, RuntimeBackend};
use bcnn::dataset::synth;
use bcnn::input::binarize::Scheme;
use bcnn::registry::ModelRegistry;
use bcnn::runtime::Artifacts;
use bcnn::server::Server;
use bcnn::util::cli::Args;

use bcnn::util::error::AppResult;

fn main() -> AppResult<()> {
    let raw: Vec<String> = std::env::args().skip(1).collect();
    let a = Args::new("serve example", "end-to-end serving driver (paper protocol)")
        .opt("artifacts", "artifacts", "artifacts directory")
        .opt("requests", "1000", "requests per variant")
        .opt("max-batch", "1", "batcher max batch size")
        .flag("pjrt", "serve HLO artifacts through PJRT instead of the engine")
        .parse(&raw)
        .map_err(|e| bcnn::app_err!("{e}"))?;

    let dir = a.get("artifacts");
    let n = a.get_usize("requests")?;
    let max_batch = a.get_usize("max-batch")?;
    let artifacts = Arc::new(Artifacts::load(&dir)?);
    let use_pjrt = a.get_flag("pjrt");

    // --- build the router with float + binarized lanes -------------------
    let float_be: Arc<dyn InferBackend> = if use_pjrt {
        let names = artifacts
            .models
            .iter()
            .filter(|m| m.kind == "float")
            .map(|m| (m.batch, m.name.clone()))
            .collect();
        Arc::new(RuntimeBackend::spawn(Arc::clone(&artifacts), names, "pjrt/float")?)
    } else {
        Arc::new(EngineBackend::float(
            FloatNetwork::load(format!("{dir}/weights_float.bcnt"))?,
            1,
        ))
    };
    let bcnn_be: Arc<dyn InferBackend> = if use_pjrt {
        let names = artifacts
            .models
            .iter()
            .filter(|m| m.scheme == "rgb" && m.kind == "bcnn_ref")
            .map(|m| (m.batch, m.name.clone()))
            .collect();
        Arc::new(RuntimeBackend::spawn(Arc::clone(&artifacts), names, "pjrt/rgb")?)
    } else {
        Arc::new(EngineBackend::bcnn(
            BcnnNetwork::load(format!("{dir}/weights_bcnn_rgb.bcnt"), Scheme::Rgb)?,
            1,
        ))
    };

    let registry = ModelRegistry::builder()
        .policy(BatchPolicy {
            max_batch,
            max_wait: std::time::Duration::from_micros(200),
            ..BatchPolicy::default()
        })
        .queue_capacity(4096)
        .build();
    registry
        .publish_backend("float", 1, "float", "float", None, float_be)
        .map_err(|e| bcnn::app_err!("{e}"))?;
    registry
        .publish_backend("bcnn_rgb", 1, "bcnn", "rgb", None, bcnn_be)
        .map_err(|e| bcnn::app_err!("{e}"))?;
    let router = Arc::clone(registry.router());

    // --- the paper's protocol: n single-sample requests per variant ------
    println!(
        "paper protocol: {n} single-sample requests per variant (backend = {})",
        if use_pjrt { "pjrt" } else { "engine" }
    );
    let mut mean_us = Vec::new();
    for variant in ["float@1", "bcnn_rgb@1"] {
        let started = Instant::now();
        let mut correct = 0usize;
        for i in 0..n {
            let s = synth::render_vehicle(i, synth::DEFAULT_SEED);
            let resp = router
                .infer_blocking(variant, s.image)
                .map_err(|e| bcnn::app_err!("{e}"))?;
            bcnn::app_ensure!(resp.error.is_none(), "backend error: {:?}", resp.error);
            correct += usize::from(resp.class == s.label);
        }
        let wall = started.elapsed();
        let snap = router.metrics(variant).map_err(|e| bcnn::app_err!("{e}"))?.snapshot();
        let e2e = snap.get("e2e_us").unwrap();
        let mean = e2e.get("mean").unwrap().as_f64().unwrap();
        mean_us.push(mean);
        println!(
            "\n[{variant}] accuracy {:.2}% | mean {:.1} µs  p50 {:.1}  p95 {:.1}  p99 {:.1} | {:.0} req/s",
            100.0 * correct as f64 / n as f64,
            mean,
            e2e.get("p50").unwrap().as_f64().unwrap(),
            e2e.get("p95").unwrap().as_f64().unwrap(),
            e2e.get("p99").unwrap().as_f64().unwrap(),
            n as f64 / wall.as_secs_f64(),
        );
    }
    println!(
        "\nbinarized speedup (e2e mean): {:.2}x  (paper GTX1080: 7.2x, Tegra X2: 5.5x, Mali: 1.7x)",
        mean_us[0] / mean_us[1]
    );

    // --- burst through the TCP front end ---------------------------------
    let server = Arc::new(Server::new(
        Arc::clone(&registry),
        CLASSES.iter().map(|s| s.to_string()).collect(),
    ));
    let stop = Arc::new(AtomicBool::new(false));
    let addr = Arc::clone(&server).serve("127.0.0.1:0", 4, Arc::clone(&stop))?;
    println!("\nTCP burst: 4 clients x 25 requests against {addr}");
    let t0 = Instant::now();
    let mut handles = Vec::new();
    for c in 0..4 {
        handles.push(std::thread::spawn(move || {
            use std::io::{BufRead, BufReader, Write};
            let mut conn = std::net::TcpStream::connect(addr).unwrap();
            let mut reader = BufReader::new(conn.try_clone().unwrap());
            let mut ok = 0;
            for i in 0..25 {
                let req = format!(
                    "{{\"op\":\"classify_synth\",\"model\":\"bcnn_rgb\",\"index\":{}}}\n",
                    c * 25 + i
                );
                conn.write_all(req.as_bytes()).unwrap();
                let mut line = String::new();
                reader.read_line(&mut line).unwrap();
                ok += usize::from(line.contains("\"ok\":true"));
            }
            ok
        }));
    }
    let total: usize = handles.into_iter().map(|h| h.join().unwrap()).sum();
    println!(
        "TCP burst done: {total}/100 ok in {:.1} ms",
        t0.elapsed().as_secs_f64() * 1e3
    );
    stop.store(true, std::sync::atomic::Ordering::Relaxed);
    router.shutdown();
    Ok(())
}
