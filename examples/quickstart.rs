//! Quickstart: load the binarized vehicle classifier and classify one
//! synthetic image through both execution paths:
//!
//!  * the pure-Rust engine (`bcnn::bnn::network::BcnnNetwork`), and
//!  * the AOT HLO artifact via PJRT (`bcnn::runtime::ModelRuntime`),
//!
//! verifying that the two agree on the class decision.
//!
//! Run after `make artifacts`:
//!     cargo run --release --example quickstart

use bcnn::bnn::network::{argmax, BcnnNetwork, CLASSES};
use bcnn::dataset::synth;
use bcnn::input::binarize::Scheme;
use bcnn::runtime::{Artifacts, ModelRuntime};

use bcnn::util::error::AppResult;

fn main() -> AppResult<()> {
    let artifacts = Artifacts::load("artifacts")
        .map_err(|e| bcnn::app_err!("{e}\nhint: run `make artifacts` first"))?;

    // 1. render a synthetic vehicle (the test-set images live in
    //    artifacts/testset.bcnt; here we draw a fresh one)
    let sample = synth::render_vehicle(42, synth::DEFAULT_SEED);
    println!("input: synthetic sample #42, true class = {}", CLASSES[sample.label]);

    // 2. pure-Rust engine
    let net = BcnnNetwork::load(artifacts.path_of("weights_bcnn_rgb.bcnt"), Scheme::Rgb)?;
    let start = std::time::Instant::now();
    let (logits, times) = net.forward(&sample.image);
    let engine_us = start.elapsed().as_nanos() as f64 / 1_000.0;
    let engine_class = argmax(&logits);
    println!("\n[engine]  class = {} ({})", engine_class, CLASSES[engine_class]);
    println!("[engine]  logits = {logits:?}");
    println!("[engine]  latency = {engine_us:.1} µs, per-layer:");
    for (name, d) in &times {
        println!("            {:<18}{:>10.1} µs", name, d.as_nanos() as f64 / 1_000.0);
    }

    // 3. the AOT HLO artifact through PJRT (same weights, same bits)
    let client = bcnn::runtime::client::cpu_client()?;
    let rt = ModelRuntime::load(&client, &artifacts, "model_bcnn_rgb_ref_b1")?;
    let start = std::time::Instant::now();
    let hlo_logits = rt.infer(&sample.image)?;
    let hlo_us = start.elapsed().as_nanos() as f64 / 1_000.0;
    let hlo_class = argmax(&hlo_logits);
    println!("\n[pjrt]    class = {} ({})", hlo_class, CLASSES[hlo_class]);
    println!("[pjrt]    logits = {hlo_logits:?}");
    println!("[pjrt]    latency = {hlo_us:.1} µs (first call; compile+upload amortized at load)");

    bcnn::app_ensure!(engine_class == hlo_class, "engine and HLO disagree!");
    println!("\nengine and PJRT agree ✓");
    if artifacts.trained.iter().any(|(k, t)| k == "rgb" && *t) {
        println!("(trained weights — prediction is meaningful)");
    } else {
        println!("(random-init weights — run `make train` for Table-3 accuracy)");
    }
    Ok(())
}
