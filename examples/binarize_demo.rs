//! Figure 1 reproduction: visualize the input-binarization schemes.
//!
//! Writes, for a few dataset samples, the original image plus its RGB-
//! thresholded and LBP-binarized versions (channels as grayscale maps)
//! to `out/fig1/*.ppm|pgm` — the panels of the paper's Figure 1.
//!
//!     cargo run --release --example binarize_demo

use bcnn::dataset::synth;
use bcnn::input::binarize;
use bcnn::input::image::{pm1_to_unit, write_pgm, write_ppm};

use bcnn::util::error::AppResult;

fn main() -> AppResult<()> {
    let out = "out/fig1";
    std::fs::create_dir_all(out)?;
    let (h, w) = (96usize, 96usize);
    // learned threshold if artifacts exist, else the init value
    let t = match bcnn::util::tensorio::TensorFile::load("artifacts/weights_bcnn_rgb.bcnt") {
        Ok(tf) => {
            let v = tf.f32("input_t")?;
            [v[0], v[1], v[2]]
        }
        Err(_) => [-0.5, -0.5, -0.5],
    };
    println!("RGB threshold T = {t:?}");

    for idx in [0usize, 1, 2, 3] {
        let s = synth::render_vehicle(idx, synth::DEFAULT_SEED);
        let cls = synth::CLASSES[s.label];

        // row 0: the original sample
        write_ppm(format!("{out}/{idx}_{cls}_orig.ppm"), &s.image, h, w)?;

        // row 1 (Figure 1 top): RGB thresholding — binarized RGB recombined
        let rgb = binarize::threshold_rgb(&s.image, &t);
        write_ppm(format!("{out}/{idx}_{cls}_thresh_rgb.ppm"), &pm1_to_unit(&rgb), h, w)?;

        // row 2 (Figure 1 bottom): LBP — 3 artificial channels
        let lbp = binarize::lbp(&s.image, h, w);
        let lbp_unit = pm1_to_unit(&lbp);
        write_ppm(format!("{out}/{idx}_{cls}_lbp_rgb.ppm"), &lbp_unit, h, w)?;
        for ch in 0..3 {
            let chan: Vec<f32> = lbp_unit.chunks_exact(3).map(|p| p[ch]).collect();
            write_pgm(format!("{out}/{idx}_{cls}_lbp_c{ch}.pgm"), &chan, h, w)?;
        }

        // extra: grayscale threshold panel
        let gray = binarize::threshold_gray(&s.image, t[0]);
        write_pgm(format!("{out}/{idx}_{cls}_thresh_gray.pgm"), &pm1_to_unit(&gray), h, w)?;

        println!("sample {idx} ({cls}): orig / thresh_rgb / lbp panels written");
    }
    println!("\nFigure-1 panels in {out}/ (PPM/PGM, any image viewer opens them)");
    Ok(())
}
