#!/usr/bin/env python3
"""Check that intra-repo markdown links resolve.

Scans the repo's markdown tree (docs/, root-level *.md, and README.md
files under rust/ and python/) for inline links `[text](target)` and
verifies that every relative target exists, and that `#anchor`
fragments pointing into markdown files match a real heading (GitHub
slug rules: lowercase, spaces to dashes, punctuation stripped).

External links (http/https/mailto) are ignored — this guards the docs
tree against silent rot when files move, not against the internet.

Exit status: 0 when every link resolves, 1 otherwise (one line per
broken link). Wired into CI next to `cargo doc`; run locally with:

    python3 scripts/check_docs_links.py
"""

import re
import sys
from pathlib import Path

REPO = Path(__file__).resolve().parent.parent

# [text](target) — stop at the first unescaped ')'; tolerate titles
# like (path "title"); skip images by treating them the same (their
# targets must resolve too).
LINK_RE = re.compile(r"\[[^\]]*\]\(([^)\s]+)(?:\s+\"[^\"]*\")?\)")
HEADING_RE = re.compile(r"^#{1,6}\s+(.*)$")
CODE_FENCE_RE = re.compile(r"^\s*(```|~~~)")


def markdown_files() -> list[Path]:
    files = set(REPO.glob("*.md"))
    files.update(REPO.glob("docs/**/*.md"))
    files.update(REPO.glob("rust/**/*.md"))
    files.update(REPO.glob("python/**/*.md"))
    files.update(REPO.glob("scripts/**/*.md"))
    return sorted(p for p in files if ".pytest_cache" not in p.parts)


def github_slug(heading: str) -> str:
    """GitHub's anchor slug: strip markdown emphasis/code, lowercase,
    drop punctuation, spaces become dashes."""
    text = re.sub(r"[`*_]", "", heading).strip()
    text = re.sub(r"\[([^\]]*)\]\([^)]*\)", r"\1", text)  # unwrap links
    text = text.lower()
    text = re.sub(r"[^\w\- ]", "", text)
    return text.replace(" ", "-")


def heading_slugs(path: Path) -> set[str]:
    slugs: set[str] = set()
    seen: dict[str, int] = {}
    in_fence = False
    for line in path.read_text(encoding="utf-8").splitlines():
        if CODE_FENCE_RE.match(line):
            in_fence = not in_fence
            continue
        if in_fence:
            continue
        m = HEADING_RE.match(line)
        if not m:
            continue
        slug = github_slug(m.group(1))
        n = seen.get(slug, 0)
        seen[slug] = n + 1
        slugs.add(slug if n == 0 else f"{slug}-{n}")
        slugs.add(slug)
    return slugs


def iter_links(path: Path):
    in_fence = False
    for lineno, line in enumerate(path.read_text(encoding="utf-8").splitlines(), 1):
        if CODE_FENCE_RE.match(line):
            in_fence = not in_fence
            continue
        if in_fence:
            continue
        for m in LINK_RE.finditer(line):
            yield lineno, m.group(1)


def main() -> int:
    errors = []
    for md in markdown_files():
        for lineno, target in iter_links(md):
            if re.match(r"^[a-z][a-z0-9+.-]*:", target):  # http:, mailto:, …
                continue
            target, _, anchor = target.partition("#")
            if target:
                resolved = (md.parent / target).resolve()
                if not resolved.exists():
                    errors.append(f"{md.relative_to(REPO)}:{lineno}: broken link -> {target}")
                    continue
            else:
                resolved = md  # pure-anchor link into the same file
            if anchor and resolved.suffix == ".md":
                if anchor not in heading_slugs(resolved):
                    errors.append(
                        f"{md.relative_to(REPO)}:{lineno}: missing anchor "
                        f"#{anchor} in {resolved.relative_to(REPO)}"
                    )
    for e in errors:
        print(e)
    checked = len(markdown_files())
    if errors:
        print(f"\n{len(errors)} broken link(s) across {checked} markdown files")
        return 1
    print(f"ok: all intra-repo links resolve across {checked} markdown files")
    return 0


if __name__ == "__main__":
    sys.exit(main())
