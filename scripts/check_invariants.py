#!/usr/bin/env python3
"""Source-invariant lint suite for the Rust tree.

Three invariants that rustc cannot enforce but the codebase relies on:

A. Write-coverage contracts: every public `*_into` kernel under
   `rust/src/bnn/` documents its output-buffer coverage (a doc line
   containing "Write coverage:") AND is referenced from the file's
   `#[cfg(test)]` region — the contract line must have a test backing
   it, or it is a promise nobody checks.

B. Panic policy in the serving plane (`rust/src/server/`,
   `rust/src/coordinator/`, `rust/src/registry/`): a bare `.unwrap()`
   outside `#[cfg(test)]` is allowed only for lock/condvar poisoning
   (the preceding context contains `.lock()`, `.read()`, `.write()`,
   `.wait(` or `.wait_timeout(` — poisoning means a worker already
   panicked, so propagating is the correct response); everything else
   must use `.expect("non-empty reason")` or a structured error.

C. Error-enum uniformity: every `enum *Error` outside `#[cfg(test)]`
   goes through `util::error::error_enum_impls!` in the same file, so
   Display/Error/From stay mechanically consistent crate-wide.

Exit status: 0 when every invariant holds, 1 otherwise (one line per
violation).  Wired into CI next to `check_docs_links.py`; run locally
with:

    python3 scripts/check_invariants.py
"""

import re
import sys
from pathlib import Path

REPO = Path(__file__).resolve().parent.parent

# context window (in comment-stripped chars) searched before a bare
# .unwrap() for a lock/condvar acquisition that justifies it
LOCK_CONTEXT_CHARS = 120
LOCK_PATTERNS = (".lock()", ".read()", ".write()", ".wait(", ".wait_timeout(")

PUB_INTO_RE = re.compile(r"^\s*pub fn (\w+_into)\b")
CFG_TEST_RE = re.compile(r"^\s*#\[cfg\(test\)\]")
UNWRAP_RE = re.compile(r"\.unwrap\(\)")
EXPECT_RE = re.compile(r"\.expect\(")
EXPECT_MSG_RE = re.compile(r'\.expect\(\s*"([^"]*)"')
ERROR_ENUM_RE = re.compile(r"^\s*(?:pub(?:\([^)]*\))?\s+)?enum (\w*Error)\b")


def rust_files(root: Path) -> list[Path]:
    return sorted(root.rglob("*.rs"))


def split_prod_test(lines: list[str]) -> tuple[list[str], list[str]]:
    """Split a file's lines at the first `#[cfg(test)]` attribute —
    everything from there to EOF counts as the test region."""
    for i, line in enumerate(lines):
        if CFG_TEST_RE.match(line):
            return lines[:i], lines[i:]
    return lines, []


def strip_line_comments(lines: list[str]) -> list[str]:
    """Drop `//`-to-EOL (incl. `///` and `//!`) so commented-out code
    and doc examples never trip the scanners.  Naive about `//` inside
    string literals, which this codebase does not use in scanned code."""
    return [line.split("//", 1)[0] for line in lines]


def doc_block_above(lines: list[str], fn_idx: int) -> list[str]:
    """The contiguous `///` doc lines immediately above `lines[fn_idx]`
    (attribute lines like `#[inline]` may sit between doc and fn)."""
    docs: list[str] = []
    i = fn_idx - 1
    while i >= 0:
        s = lines[i].strip()
        if s.startswith("#["):
            i -= 1
            continue
        if s.startswith("///"):
            docs.append(s)
            i -= 1
            continue
        break
    return docs


def check_write_coverage(repo: Path) -> list[str]:
    errors = []
    for path in rust_files(repo / "rust" / "src" / "bnn"):
        lines = path.read_text(encoding="utf-8").splitlines()
        prod, test = split_prod_test(lines)
        test_text = "\n".join(test)
        for idx, line in enumerate(prod):
            m = PUB_INTO_RE.match(line)
            if not m:
                continue
            name = m.group(1)
            rel = path.relative_to(repo)
            docs = doc_block_above(prod, idx)
            if not any("Write coverage:" in d for d in docs):
                errors.append(
                    f"{rel}:{idx + 1}: pub fn {name} lacks a "
                    f'"Write coverage:" contract line in its doc comment'
                )
            if not re.search(rf"\b{name}\b", test_text):
                errors.append(
                    f"{rel}:{idx + 1}: pub fn {name} is never referenced "
                    f"in this file's #[cfg(test)] region"
                )
    return errors


def check_panic_policy(repo: Path) -> list[str]:
    errors = []
    for sub in ("server", "coordinator", "registry"):
        for path in rust_files(repo / "rust" / "src" / sub):
            lines = path.read_text(encoding="utf-8").splitlines()
            prod, _ = split_prod_test(lines)
            text = "\n".join(strip_line_comments(prod))
            rel = path.relative_to(repo)
            for m in UNWRAP_RE.finditer(text):
                ctx = text[max(0, m.start() - LOCK_CONTEXT_CHARS) : m.start()]
                if not any(p in ctx for p in LOCK_PATTERNS):
                    lineno = text.count("\n", 0, m.start()) + 1
                    errors.append(
                        f"{rel}:{lineno}: bare .unwrap() outside a "
                        f"lock/condvar acquisition — use .expect(reason) "
                        f"or a structured error"
                    )
            for m in EXPECT_RE.finditer(text):
                msg = EXPECT_MSG_RE.match(text, m.start())
                if msg is None or not msg.group(1).strip():
                    lineno = text.count("\n", 0, m.start()) + 1
                    errors.append(
                        f"{rel}:{lineno}: .expect() without a non-empty "
                        f"string-literal reason"
                    )
    return errors


def check_error_enums(repo: Path) -> list[str]:
    errors = []
    for path in rust_files(repo / "rust" / "src"):
        lines = path.read_text(encoding="utf-8").splitlines()
        prod, _ = split_prod_test(lines)
        prod_text = "\n".join(prod)
        for idx, line in enumerate(prod):
            m = ERROR_ENUM_RE.match(line)
            if not m:
                continue
            name = m.group(1)
            if f"error_enum_impls!({name}" not in prod_text:
                errors.append(
                    f"{path.relative_to(repo)}:{idx + 1}: enum {name} does "
                    f"not go through error_enum_impls! in this file"
                )
    return errors


def main() -> int:
    errors = (
        check_write_coverage(REPO) + check_panic_policy(REPO) + check_error_enums(REPO)
    )
    for e in errors:
        print(e)
    if errors:
        print(f"\n{len(errors)} invariant violation(s)")
        return 1
    print("ok: write-coverage, panic-policy, and error-enum invariants hold")
    return 0


if __name__ == "__main__":
    sys.exit(main())
