#!/usr/bin/env python3
"""Source-invariant lint suite for the Rust tree.

Six invariants that rustc cannot enforce but the codebase relies on:

A. Write-coverage contracts: every public `*_into` kernel under
   `rust/src/bnn/` documents its output-buffer coverage (a doc line
   containing "Write coverage:") AND is referenced from the file's
   `#[cfg(test)]` region — the contract line must have a test backing
   it, or it is a promise nobody checks.

B. Panic policy in the serving plane (`rust/src/server/`,
   `rust/src/coordinator/`, `rust/src/registry/`): a bare `.unwrap()`
   outside `#[cfg(test)]` is allowed only for lock/condvar poisoning
   (the preceding context contains `.lock()`, `.read()`, `.write()`,
   `.wait(` or `.wait_timeout(` — poisoning means a worker already
   panicked, so propagating is the correct response); everything else
   must use `.expect("non-empty reason")` or a structured error.

C. Error-enum uniformity: every `enum *Error` outside `#[cfg(test)]`
   goes through `util::error::error_enum_impls!` in the same file, so
   Display/Error/From stay mechanically consistent crate-wide.

D. Variant coverage for the plan IR and its proof machinery: every
   `LayerOp` and `StepKind` variant must appear (backticked) in a table
   row of docs/ARCHITECTURE.md — the op/step effect inventory is the
   verifier's public contract, and an undocumented kind is a contract
   hole; and every `Corruption` and `EquivError` variant must be named
   by at least one `#[cfg(test)]` region (`Enum::Variant`) — a
   corruption class nobody injects, or a refusal variant nobody
   asserts, is dead proof surface.

E. Metric inventory coverage: every Prometheus metric family the
   server can emit — a production string literal wholly matching
   `bcnn_[a-z0-9_]+` under `rust/src/server/`, where the exposition is
   rendered — must appear backticked in a table row of
   docs/ARCHITECTURE.md.  The metric inventory is the operator's
   contract with dashboards and alerts; an undocumented family is a
   silent interface.

F. Unsafe audit boundary: the crate root carries `#![deny(unsafe_code)]`
   and exactly one module — the audited SIMD microkernel
   (`rust/src/bnn/microkernel/simd.rs`) — may opt back out with
   `allow(unsafe_code)`; an opt-out anywhere else silently widens the
   audited surface.  And every `#[target_feature]` function (the only
   place `unsafe` appears) must be named by a `#[cfg(test)]` region or
   an integration test — a vector kernel without a bit-identity test
   pinning it to the scalar reference is an unaudited fast path.

Exit status: 0 when every invariant holds, 1 otherwise (one line per
violation).  Wired into CI next to `check_docs_links.py`; run locally
with:

    python3 scripts/check_invariants.py
"""

import re
import sys
from pathlib import Path

REPO = Path(__file__).resolve().parent.parent

# context window (in comment-stripped chars) searched before a bare
# .unwrap() for a lock/condvar acquisition that justifies it
LOCK_CONTEXT_CHARS = 120
LOCK_PATTERNS = (".lock()", ".read()", ".write()", ".wait(", ".wait_timeout(")

PUB_INTO_RE = re.compile(r"^\s*pub fn (\w+_into)\b")
CFG_TEST_RE = re.compile(r"^\s*#\[cfg\(test\)\]")
UNWRAP_RE = re.compile(r"\.unwrap\(\)")
EXPECT_RE = re.compile(r"\.expect\(")
EXPECT_MSG_RE = re.compile(r'\.expect\(\s*"([^"]*)"')
ERROR_ENUM_RE = re.compile(r"^\s*(?:pub(?:\([^)]*\))?\s+)?enum (\w*Error)\b")


def rust_files(root: Path) -> list[Path]:
    return sorted(root.rglob("*.rs"))


def split_prod_test(lines: list[str]) -> tuple[list[str], list[str]]:
    """Split a file's lines at the first `#[cfg(test)]` attribute —
    everything from there to EOF counts as the test region."""
    for i, line in enumerate(lines):
        if CFG_TEST_RE.match(line):
            return lines[:i], lines[i:]
    return lines, []


def strip_line_comments(lines: list[str]) -> list[str]:
    """Drop `//`-to-EOL (incl. `///` and `//!`) so commented-out code
    and doc examples never trip the scanners.  Naive about `//` inside
    string literals, which this codebase does not use in scanned code."""
    return [line.split("//", 1)[0] for line in lines]


def doc_block_above(lines: list[str], fn_idx: int) -> list[str]:
    """The contiguous `///` doc lines immediately above `lines[fn_idx]`
    (attribute lines like `#[inline]` may sit between doc and fn)."""
    docs: list[str] = []
    i = fn_idx - 1
    while i >= 0:
        s = lines[i].strip()
        if s.startswith("#["):
            i -= 1
            continue
        if s.startswith("///"):
            docs.append(s)
            i -= 1
            continue
        break
    return docs


def check_write_coverage(repo: Path) -> list[str]:
    errors = []
    for path in rust_files(repo / "rust" / "src" / "bnn"):
        lines = path.read_text(encoding="utf-8").splitlines()
        prod, test = split_prod_test(lines)
        test_text = "\n".join(test)
        for idx, line in enumerate(prod):
            m = PUB_INTO_RE.match(line)
            if not m:
                continue
            name = m.group(1)
            rel = path.relative_to(repo)
            docs = doc_block_above(prod, idx)
            if not any("Write coverage:" in d for d in docs):
                errors.append(
                    f"{rel}:{idx + 1}: pub fn {name} lacks a "
                    f'"Write coverage:" contract line in its doc comment'
                )
            if not re.search(rf"\b{name}\b", test_text):
                errors.append(
                    f"{rel}:{idx + 1}: pub fn {name} is never referenced "
                    f"in this file's #[cfg(test)] region"
                )
    return errors


def check_panic_policy(repo: Path) -> list[str]:
    errors = []
    for sub in ("server", "coordinator", "registry"):
        for path in rust_files(repo / "rust" / "src" / sub):
            lines = path.read_text(encoding="utf-8").splitlines()
            prod, _ = split_prod_test(lines)
            text = "\n".join(strip_line_comments(prod))
            rel = path.relative_to(repo)
            for m in UNWRAP_RE.finditer(text):
                ctx = text[max(0, m.start() - LOCK_CONTEXT_CHARS) : m.start()]
                if not any(p in ctx for p in LOCK_PATTERNS):
                    lineno = text.count("\n", 0, m.start()) + 1
                    errors.append(
                        f"{rel}:{lineno}: bare .unwrap() outside a "
                        f"lock/condvar acquisition — use .expect(reason) "
                        f"or a structured error"
                    )
            for m in EXPECT_RE.finditer(text):
                msg = EXPECT_MSG_RE.match(text, m.start())
                if msg is None or not msg.group(1).strip():
                    lineno = text.count("\n", 0, m.start()) + 1
                    errors.append(
                        f"{rel}:{lineno}: .expect() without a non-empty "
                        f"string-literal reason"
                    )
    return errors


def check_error_enums(repo: Path) -> list[str]:
    errors = []
    for path in rust_files(repo / "rust" / "src"):
        lines = path.read_text(encoding="utf-8").splitlines()
        prod, _ = split_prod_test(lines)
        prod_text = "\n".join(prod)
        for idx, line in enumerate(prod):
            m = ERROR_ENUM_RE.match(line)
            if not m:
                continue
            name = m.group(1)
            if f"error_enum_impls!({name}" not in prod_text:
                errors.append(
                    f"{path.relative_to(repo)}:{idx + 1}: enum {name} does "
                    f"not go through error_enum_impls! in this file"
                )
    return errors


# rule D: enums whose variants must appear in ARCHITECTURE.md's tables
DOC_TABLE_ENUMS = (
    ("LayerOp", "rust/src/bnn/graph/mod.rs"),
    ("StepKind", "rust/src/bnn/graph/plan.rs"),
)
# rule D: enums whose variants must each be named by >= 1 test
TEST_NAMED_ENUMS = (
    ("Corruption", "rust/src/bnn/graph/plan.rs"),
    ("EquivError", "rust/src/bnn/graph/equiv.rs"),
)

ENUM_OPEN_RE_TMPL = r"^\s*(?:pub(?:\([^)]*\))?\s+)?enum {name}\b"
VARIANT_RE = re.compile(r"^\s*([A-Z]\w*)\s*(?:\{|\(|,|=|$)")


def enum_variants(path: Path, name: str) -> list[str]:
    """Variant identifiers of `enum name` in `path`, by brace-depth walk
    (variants sit at depth 1; struct-variant fields at depth 2+)."""
    if not path.is_file():
        return []
    open_re = re.compile(ENUM_OPEN_RE_TMPL.format(name=re.escape(name)))
    lines = strip_line_comments(path.read_text(encoding="utf-8").splitlines())
    variants: list[str] = []
    depth = 0
    inside = False
    for line in lines:
        if not inside:
            if open_re.match(line):
                inside = True
                depth = line.count("{") - line.count("}")
            continue
        if depth == 1:
            m = VARIANT_RE.match(line)
            if m:
                variants.append(m.group(1))
        depth += line.count("{") - line.count("}")
        if depth <= 0:
            break
    return variants


def check_variant_coverage(repo: Path) -> list[str]:
    errors = []
    arch = repo / "docs" / "ARCHITECTURE.md"
    table_rows = (
        [l for l in arch.read_text(encoding="utf-8").splitlines() if l.lstrip().startswith("|")]
        if arch.is_file()
        else []
    )
    for enum_name, rel in DOC_TABLE_ENUMS:
        for v in enum_variants(repo / rel, enum_name):
            if not any(f"`{v}`" in row for row in table_rows):
                errors.append(
                    f"docs/ARCHITECTURE.md: {enum_name} variant `{v}` missing "
                    f"from the op/step effect tables"
                )
    # test-region text across the whole Rust tree; files under
    # rust/tests/ are integration tests — the entire file counts
    test_chunks = []
    for path in rust_files(repo / "rust"):
        lines = path.read_text(encoding="utf-8").splitlines()
        if "tests" in path.parts:
            test_chunks.append("\n".join(lines))
        else:
            _, test = split_prod_test(lines)
            test_chunks.append("\n".join(test))
    test_text = "\n".join(test_chunks)
    for enum_name, rel in TEST_NAMED_ENUMS:
        for v in enum_variants(repo / rel, enum_name):
            if not re.search(rf"\b{enum_name}::{v}\b", test_text):
                errors.append(
                    f"{rel}: {enum_name}::{v} is never named by any "
                    f"#[cfg(test)] region or integration test"
                )
    return errors


# rule E: a prod string literal that IS a metric family name (both
# quotes adjacent, so lane keys like "bcnn_rgb@1" never match)
METRIC_LIT_RE = re.compile(r'"(bcnn_[a-z0-9_]+)"')


def check_metric_docs(repo: Path) -> list[str]:
    arch = repo / "docs" / "ARCHITECTURE.md"
    table_rows = (
        [l for l in arch.read_text(encoding="utf-8").splitlines() if l.lstrip().startswith("|")]
        if arch.is_file()
        else []
    )
    # first emission site per family — one report per name, not per
    # use.  Scoped to the server tree: that is where the exposition is
    # rendered, and it keeps non-metric literals elsewhere (artifact
    # kinds like "bcnn_ref") out of the inventory contract.
    sites: dict[str, str] = {}
    for path in rust_files(repo / "rust" / "src" / "server"):
        lines = path.read_text(encoding="utf-8").splitlines()
        prod, _ = split_prod_test(lines)
        text = "\n".join(strip_line_comments(prod))
        rel = path.relative_to(repo)
        for m in METRIC_LIT_RE.finditer(text):
            name = m.group(1)
            if name not in sites:
                lineno = text.count("\n", 0, m.start()) + 1
                sites[name] = f"{rel}:{lineno}"
    errors = []
    for name in sorted(sites):
        if not any(f"`{name}`" in row for row in table_rows):
            errors.append(
                f"{sites[name]}: metric `{name}` is emitted but missing from "
                f"docs/ARCHITECTURE.md's metric inventory table"
            )
    return errors


# rule F: the one module where `unsafe` is audited; an
# allow(unsafe_code) anywhere else re-opens the crate-wide deny
AUDITED_UNSAFE_FILES = ("rust/src/bnn/microkernel/simd.rs",)
ALLOW_UNSAFE_RE = re.compile(r"#!?\[\s*allow\s*\(\s*unsafe_code\s*\)\s*\]")
TARGET_FEATURE_RE = re.compile(r"^\s*#\[target_feature\(")
FN_NAME_RE = re.compile(r"\bfn\s+(\w+)")
# attribute-to-fn distance searched for the annotated function's name
TARGET_FEATURE_WINDOW = 6


def check_unsafe_audit(repo: Path) -> list[str]:
    errors = []
    feature_fns: list[tuple[str, int, str]] = []
    test_chunks: list[str] = []
    for path in rust_files(repo / "rust"):
        lines = path.read_text(encoding="utf-8").splitlines()
        rel = path.relative_to(repo)
        stripped_all = strip_line_comments(lines)
        # (a) unsafe opt-outs only inside the audited module — scanned
        # over the whole file: a test module is no safer a place to
        # widen the unsafe surface than production code
        if str(rel) not in AUDITED_UNSAFE_FILES:
            for idx, line in enumerate(stripped_all):
                if ALLOW_UNSAFE_RE.search(line):
                    errors.append(
                        f"{rel}:{idx + 1}: allow(unsafe_code) outside the "
                        f"audited SIMD microkernel module — the crate-wide "
                        f"deny stands everywhere else"
                    )
        # (b) #[target_feature] fns from production regions; test-region
        # text collected like rule D (rust/tests files count whole)
        if "tests" in path.parts:
            prod, test = [], lines
        else:
            prod, test = split_prod_test(lines)
        test_chunks.append("\n".join(strip_line_comments(test)))
        stripped_prod = strip_line_comments(prod)
        for idx, line in enumerate(stripped_prod):
            if not TARGET_FEATURE_RE.match(line):
                continue
            for fwd in stripped_prod[idx + 1 : idx + 1 + TARGET_FEATURE_WINDOW]:
                m = FN_NAME_RE.search(fwd)
                if m:
                    feature_fns.append((str(rel), idx + 1, m.group(1)))
                    break
    test_text = "\n".join(test_chunks)
    for rel, lineno, name in feature_fns:
        if not re.search(rf"\b{name}\b", test_text):
            errors.append(
                f"{rel}:{lineno}: #[target_feature] fn {name} is never named "
                f"by any #[cfg(test)] region or integration test — vector "
                f"kernels require a bit-identity test"
            )
    return errors


def main() -> int:
    errors = (
        check_write_coverage(REPO)
        + check_panic_policy(REPO)
        + check_error_enums(REPO)
        + check_variant_coverage(REPO)
        + check_metric_docs(REPO)
        + check_unsafe_audit(REPO)
    )
    for e in errors:
        print(e)
    if errors:
        print(f"\n{len(errors)} invariant violation(s)")
        return 1
    print(
        "ok: write-coverage, panic-policy, error-enum, variant-coverage, "
        "metric-docs, and unsafe-audit invariants hold"
    )
    return 0


if __name__ == "__main__":
    sys.exit(main())
